//! Flight-recorder acceptance suite — the three guarantees
//! `src/obs/mod.rs` documents:
//!
//! 1. **Off path bit-identical** — a run with no recorder installed
//!    and a run streaming every round to a JSONL sink produce the
//!    same iterates, trace, and ledger, bit for bit. Recording only
//!    *reads*; it charges no virtual time, passes, or bytes.
//! 2. **Offline replay is exact** — `RecordedRun::from_jsonl` over
//!    the recorded stream reproduces the in-process
//!    `render_run_report` markdown byte-for-byte, including the
//!    resilience table of a seeded fault run.
//! 3. **Allocation-free steady state** (`--features audit`) — after
//!    warm-up, a recorded round performs zero heap acquisitions.

use std::io;
use std::sync::{Arc, Mutex};

use psgd::algo::adapt::{Asynchrony, Quorum};
use psgd::algo::async_fs::{AsyncFsConfig, AsyncFsDriver};
use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::{Driver, StopRule};
use psgd::cluster::{Cluster, CostModel, FaultPlan};
use psgd::data::synth::SynthConfig;
use psgd::metrics::report::{render_run_report, RecordedRun};
use psgd::metrics::trace::Trace;
use psgd::obs::{JsonlRecorder, RunManifest};
use psgd::util::json;

/// Same sparse-regime data the fault suite pins.
fn make_cluster(nodes: usize, seed: u64) -> Cluster {
    let data = SynthConfig {
        n_examples: 400,
        n_features: 2_000,
        nnz_per_example: 5,
        skew: 1.0,
        ..SynthConfig::default()
    }
    .generate(seed);
    let mut c = Cluster::partition(data, nodes, CostModel::free());
    c.threads = 1;
    c
}

fn fs_config() -> FsConfig {
    FsConfig { lam: 0.5, epochs: 2, ..Default::default() }
}

fn async_config(nodes: usize) -> AsyncFsConfig {
    AsyncFsConfig {
        fs: fs_config(),
        policy: Asynchrony::Bounded {
            tau: 2,
            quorum: Quorum::AtLeast(nodes - 1),
        },
        ..Default::default()
    }
}

/// `io::Write` sink whose buffer outlives the recorder: the cluster
/// owns the boxed recorder, so the test reads the stream back through
/// this shared handle after `finish_recording()` drops it.
#[derive(Clone)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl SharedBuf {
    fn new() -> SharedBuf {
        SharedBuf(Arc::new(Mutex::new(Vec::new())))
    }

    fn take_string(&self) -> String {
        String::from_utf8(self.0.lock().unwrap().clone()).unwrap()
    }
}

impl io::Write for SharedBuf {
    fn write(&mut self, b: &[u8]) -> io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(b);
        Ok(b.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

fn assert_traces_identical(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{what}: iteration counts");
    for (p, q) in a.points.iter().zip(&b.points) {
        assert_eq!(p.f, q.f, "{what}: objective diverged at iter {}", p.iter);
        assert_eq!(
            p.comm_passes, q.comm_passes,
            "{what}: pass accounting diverged at iter {}",
            p.iter
        );
        assert_eq!(
            p.seconds, q.seconds,
            "{what}: simulated seconds diverged at iter {}",
            p.iter
        );
        assert_eq!(
            p.safeguard_hits, q.safeguard_hits,
            "{what}: safeguard counts diverged at iter {}",
            p.iter
        );
    }
}

#[test]
fn recording_leaves_sync_fs_bit_identical() {
    let nodes = 4;
    let mut bare = make_cluster(nodes, 2);
    let mut taped = make_cluster(nodes, 2);
    taped.set_recorder(Box::new(JsonlRecorder::new(SharedBuf::new())));

    let run_bare =
        FsDriver::new(fs_config()).run(&mut bare, None, &StopRule::iters(8));
    let run_taped =
        FsDriver::new(fs_config()).run(&mut taped, None, &StopRule::iters(8));
    taped.finish_recording();

    assert_eq!(run_bare.w, run_taped.w, "recording perturbed the iterates");
    assert_traces_identical(&run_bare.trace, &run_taped.trace, "sync FS");
    assert_eq!(bare.ledger, taped.ledger, "recording charged the ledger");
}

#[test]
fn recording_leaves_seeded_fault_async_fs_bit_identical() {
    let nodes = 5;
    let run = |record: bool| {
        let mut cluster = make_cluster(nodes, 3);
        cluster.set_fault_plan(FaultPlan::seeded(nodes, 1));
        if record {
            cluster
                .set_recorder(Box::new(JsonlRecorder::new(SharedBuf::new())));
        }
        let run = AsyncFsDriver::new(async_config(nodes)).run(
            &mut cluster,
            None,
            &StopRule::iters(20),
        );
        cluster.finish_recording();
        (run, cluster.ledger.clone())
    };

    let (run_bare, ledger_bare) = run(false);
    let (run_taped, ledger_taped) = run(true);

    assert!(
        ledger_bare.has_fault_activity(),
        "seeded weather was a no-op; the test lost its teeth"
    );
    assert_eq!(run_bare.w, run_taped.w, "recording perturbed the iterates");
    assert_traces_identical(&run_bare.trace, &run_taped.trace, "async FS");
    assert_eq!(ledger_bare, ledger_taped, "recording charged the ledger");
}

#[test]
fn recorded_stream_replays_the_in_process_report_byte_for_byte() {
    let nodes = 5;
    let mut cluster = make_cluster(nodes, 3);
    cluster.set_fault_plan(FaultPlan::seeded(nodes, 1));
    let sink = SharedBuf::new();
    cluster.set_recorder(Box::new(JsonlRecorder::new(sink.clone())));
    cluster.record_manifest(&RunManifest {
        method: "afs".to_string(),
        nodes,
        threads: 1,
        examples: 400,
        features: 2_000,
        loss: "logistic".to_string(),
        lam: 0.5,
        iters: 20,
        seed: 3,
        master: "auto".to_string(),
        staleness: Some(2),
        quorum: Some(nodes - 1),
        policy: Some(async_config(nodes).policy.tag()),
        fault: Some("seeded".to_string()),
        fault_seed: Some(1),
        ..RunManifest::default()
    });

    let run = AsyncFsDriver::new(async_config(nodes)).run(
        &mut cluster,
        None,
        &StopRule::iters(20),
    );
    cluster.finish_recording();

    let text = sink.take_string();
    // schema sanity: manifest first, then one record per round in
    // round order (from_jsonl enforces the ordering)
    let first = json::parse(text.lines().next().unwrap()).unwrap();
    assert_eq!(first.get("kind").unwrap().as_str(), Some("manifest"));
    let recorded = RecordedRun::from_jsonl(&text).expect("stream must parse");
    assert_eq!(
        recorded.rounds.len(),
        run.trace.points.len(),
        "one round record per trace point"
    );

    // the acceptance bar: the offline report over the stream is the
    // in-process report, byte for byte — trace, resilience counters,
    // staleness histogram, recovery seconds, f* included
    let offline = recorded.report();
    let in_process = render_run_report(&run.trace, &run.ledger, run.f);
    assert!(
        run.ledger.has_fault_activity(),
        "seeded weather was a no-op; the resilience table is empty"
    );
    assert_eq!(offline, in_process, "offline replay diverged");
}

/// Steady-state recording is allocation-free: after the line buffer is
/// warmed, a `round()` call touches only reused storage and
/// `core::fmt`'s stack buffers. The watch loop tolerates concurrent
/// sibling tests (the counting allocator is process-global) by
/// requiring *some* iteration to observe zero acquisitions.
#[cfg(feature = "audit")]
#[test]
fn steady_state_round_recording_allocates_nothing() {
    use psgd::audit::AllocWatch;
    use psgd::obs::{Recorder, RoundRecord};

    let mut rec = JsonlRecorder::new(io::sink());
    rec.manifest(&RunManifest {
        method: "afs".to_string(),
        nodes: 8,
        ..RunManifest::default()
    });
    let mut r = RoundRecord::with_capacity(8);
    r.round = 7;
    r.f = 0.517_328_114_2;
    r.gnorm = 1.25e-3;
    r.auprc = f64::NAN;
    r.passes = 44.0;
    r.secs = 3.5;
    r.sg_hits = 2;
    r.sg_replaced.extend([1, 5]);
    r.combined_ok = Some(true);
    r.step = Some(0.5);
    r.ls_evals = Some(3);
    r.is_async = true;
    r.quorum.extend([0, 1, 2, 3, 5, 6, 7]);
    r.staleness.extend([0, 1, 0, 0, 2, 0, 1]);
    r.members.extend(0..8);
    r.fault_nodes.push(4);
    r.fault_whats.push("crash");
    r.live_u = 1_793;
    r.d_passes = 4.0;
    r.d_bytes = 57_376.0;
    r.d_scalar = 1;
    r.d_makespan = 0.125;
    r.d_level_bytes.extend([28_688.0, 14_344.0, 14_344.0]);
    r.recovery_s = 0.25;
    r.retry_s = 0.125;
    r.link_retries = 2;
    r.reroutes = 1;
    r.spec_hits = 3;
    r.spec_misses = 1;
    r.ctrl_tau = Some(2);
    r.ctrl_q = Some(6);

    // warm-up: size the line buffer past the widest line we'll emit
    for _ in 0..4 {
        rec.round(&r);
    }

    let mut best = usize::MAX;
    for _ in 0..2_000 {
        let watch = AllocWatch::begin();
        rec.round(&r);
        best = best.min(watch.allocations());
        if best == 0 {
            break;
        }
        std::thread::yield_now();
    }
    assert_eq!(
        best, 0,
        "a warmed round() call made {best} heap acquisitions"
    );
}
