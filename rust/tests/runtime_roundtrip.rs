//! The authoritative three-layer composition check: load the AOT
//! artifacts (L2 JAX graph embedding the L1 Pallas kernels) through the
//! PJRT runtime and compare every executable against the L3 Rust
//! oracle on random data at the manifest's baked shapes.
//!
//! Requires `make artifacts` to have run; skips (with a notice) when
//! artifacts/ is absent so `cargo test` works on a fresh checkout.

use psgd::linalg::Csr;
use psgd::loss::LossKind;
use psgd::runtime::DenseRuntime;
use psgd::util::rng::Rng;

fn runtime() -> Option<DenseRuntime> {
    match DenseRuntime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP runtime tests (run `make artifacts`): {e:#}");
            None
        }
    }
}

struct DenseProblem {
    n: usize,
    d: usize,
    x: Vec<f32>,
    y: Vec<f32>,
    w: Vec<f32>,
}

fn problem(rt: &DenseRuntime, seed: u64) -> DenseProblem {
    let (n, d) = (rt.manifest.n, rt.manifest.d);
    let mut rng = Rng::new(seed);
    DenseProblem {
        n,
        d,
        x: (0..n * d).map(|_| (rng.normal() * 0.3) as f32).collect(),
        y: (0..n).map(|_| rng.sign() as f32).collect(),
        w: (0..d).map(|_| (rng.normal() * 0.05) as f32).collect(),
    }
}

fn loss_kind(rt: &DenseRuntime) -> LossKind {
    LossKind::parse(&rt.manifest.loss).expect("manifest loss")
}

/// Rust-side margins oracle in f64.
fn margins_oracle(p: &DenseProblem) -> Vec<f64> {
    (0..p.n)
        .map(|i| {
            (0..p.d)
                .map(|j| p.x[i * p.d + j] as f64 * p.w[j] as f64)
                .sum()
        })
        .collect()
}

#[test]
fn margins_matches_oracle() {
    let Some(rt) = runtime() else { return };
    let p = problem(&rt, 1);
    let got = rt.margins(&p.x, &p.w).expect("execute margins");
    let want = margins_oracle(&p);
    assert_eq!(got.len(), p.n);
    for i in 0..p.n {
        assert!(
            (got[i] as f64 - want[i]).abs() < 1e-3 * (1.0 + want[i].abs()),
            "margin {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn value_grad_matches_oracle() {
    let Some(rt) = runtime() else { return };
    let loss = loss_kind(&rt);
    let p = problem(&rt, 2);
    let out = rt.value_grad(&p.w, &p.x, &p.y).expect("execute value_grad");

    let z = margins_oracle(&p);
    let want_val: f64 =
        (0..p.n).map(|i| loss.value(z[i], p.y[i] as f64)).sum();
    assert!(
        (out.loss_sum - want_val).abs() < 1e-2 * (1.0 + want_val.abs()),
        "loss {} vs {}",
        out.loss_sum,
        want_val
    );
    // gradient: Xᵀ l'(z)
    let mut want_g = vec![0.0f64; p.d];
    for i in 0..p.n {
        let r = loss.deriv(z[i], p.y[i] as f64);
        for j in 0..p.d {
            want_g[j] += r * p.x[i * p.d + j] as f64;
        }
    }
    assert_eq!(out.grad.len(), p.d);
    for j in 0..p.d {
        assert!(
            (out.grad[j] as f64 - want_g[j]).abs()
                < 2e-2 * (1.0 + want_g[j].abs()),
            "grad {j}: {} vs {}",
            out.grad[j],
            want_g[j]
        );
    }
    // the margin by-product too
    for i in 0..p.n {
        assert!((out.margins[i] as f64 - z[i]).abs() < 1e-3 * (1.0 + z[i].abs()));
    }
}

#[test]
fn svrg_epoch_matches_rust_svrg() {
    // Run ONE SVRG epoch through the XLA executable and through the
    // native Rust implementation with the same permutation and
    // hyperparameters; the two layers must agree.
    let Some(rt) = runtime() else { return };
    let loss = loss_kind(&rt);
    let p = problem(&rt, 3);
    let (n, d, batch) = (rt.manifest.n, rt.manifest.d, rt.manifest.batch);
    let mut rng = Rng::new(9);
    let perm_u32 = rng.permutation(n);
    let perm: Vec<i32> = perm_u32.iter().map(|&i| i as i32).collect();
    let tilt: Vec<f32> = (0..d).map(|_| (rng.normal() * 0.01) as f32).collect();
    let lam = 0.1f32;
    let lr = 1e-4f32;

    let got = rt
        .svrg_epoch(&p.w, &p.x, &p.y, &tilt, lam, lr, &perm)
        .expect("execute svrg_epoch");

    // ---- Rust oracle: same update rule as model.svrg_epoch ----
    let w0: Vec<f64> = p.w.iter().map(|&v| v as f64).collect();
    let mut w = w0.clone();
    // μ = λw0 + Σ ∇l_i(w0) + tilt
    let z0 = margins_oracle(&p);
    let mut mu: Vec<f64> = (0..d)
        .map(|j| lam as f64 * w0[j] + tilt[j] as f64)
        .collect();
    for i in 0..n {
        let r = loss.deriv(z0[i], p.y[i] as f64);
        for j in 0..d {
            mu[j] += r * p.x[i * p.d + j] as f64;
        }
    }
    let nb = n / batch;
    let scale = n as f64 / batch as f64;
    for k in 0..nb {
        let idx = &perm[k * batch..(k + 1) * batch];
        let mut g: Vec<f64> = (0..d)
            .map(|j| mu[j] + lam as f64 * (w[j] - w0[j]))
            .collect();
        for &ii in idx {
            let i = ii as usize;
            let zi: f64 = (0..d).map(|j| p.x[i * d + j] as f64 * w[j]).sum();
            let z0i: f64 =
                (0..d).map(|j| p.x[i * d + j] as f64 * w0[j]).sum();
            let r = loss.deriv(zi, p.y[i] as f64)
                - loss.deriv(z0i, p.y[i] as f64);
            if r != 0.0 {
                for j in 0..d {
                    g[j] += scale * r * p.x[i * d + j] as f64;
                }
            }
        }
        for j in 0..d {
            w[j] -= lr as f64 * g[j];
        }
    }

    assert_eq!(got.len(), d);
    let mut max_rel = 0.0f64;
    for j in 0..d {
        let rel = (got[j] as f64 - w[j]).abs() / (1.0 + w[j].abs());
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 5e-3, "max relative deviation {max_rel}");
}

#[test]
fn svrg_epoch_through_runtime_descends_fhat() {
    // end-to-end sanity: the executable's epoch output decreases the
    // tilted objective it was built for
    let Some(rt) = runtime() else { return };
    let loss = loss_kind(&rt);
    let p = problem(&rt, 4);
    let (n, d) = (p.n, p.d);
    let mut rng = Rng::new(11);
    let perm: Vec<i32> =
        rng.permutation(n).into_iter().map(|i| i as i32).collect();
    let tilt = vec![0.0f32; d];
    let lam = 0.1f32;
    let lr = 1e-5f32; // conservative
    let w1 = rt
        .svrg_epoch(&p.w, &p.x, &p.y, &tilt, lam, lr, &perm)
        .expect("svrg epoch");

    // f̂ via a CSR-backed objective (tilt = 0)
    let rows: Vec<Vec<(u32, f32)>> = (0..n)
        .map(|i| (0..d).map(|j| (j as u32, p.x[i * d + j])).collect())
        .collect();
    let x = Csr::from_rows(d, &rows);
    let y: Vec<f64> = p.y.iter().map(|&v| v as f64).collect();
    let fhat = |w: &[f32]| -> f64 {
        let wd: Vec<f64> = w.iter().map(|&v| v as f64).collect();
        let mut v = 0.5 * lam as f64 * wd.iter().map(|x| x * x).sum::<f64>();
        for i in 0..n {
            v += loss.value(x.row_dot(i, &wd), y[i]);
        }
        v
    };
    assert!(
        fhat(&w1) < fhat(&p.w),
        "epoch did not descend: {} -> {}",
        fhat(&p.w),
        fhat(&w1)
    );
}

#[test]
fn runtime_rejects_wrong_shapes() {
    let Some(rt) = runtime() else { return };
    let p = problem(&rt, 5);
    assert!(rt.margins(&p.x[..p.x.len() - 1], &p.w).is_err());
    assert!(rt.value_grad(&p.w[..p.w.len() - 1], &p.x, &p.y).is_err());
}
