//! Property-based invariants (via the in-tree `util::prop` framework):
//! the structural facts the system's correctness rests on, checked over
//! randomized instances.

use psgd::algo::safeguard::Safeguard;
use psgd::cluster::allreduce::tree_sum;
use psgd::data::partition::Partition;
use psgd::data::synth::SynthConfig;
use psgd::linalg::{dense, Csr};
use psgd::loss::{LossKind, ALL_LOSSES};
use psgd::objective::{shard_loss_grad, LocalApprox, Objective};
use psgd::opt::linesearch::{strong_wolfe, MarginPhi, PhiLambda, WolfeParams};
use psgd::opt::svrg::{svrg_epochs, SvrgParams};
use psgd::util::prop::{check, check_msg};
use psgd::util::rng::Rng;

fn random_csr(rng: &mut Rng, n: usize, d: usize, nnz_per_row: usize) -> Csr {
    let rows: Vec<Vec<(u32, f32)>> = (0..n)
        .map(|_| {
            (0..1 + rng.below(nnz_per_row))
                .map(|_| (rng.below(d) as u32, rng.range(-2.0, 2.0) as f32))
                .collect()
        })
        .collect();
    Csr::from_rows(d, &rows)
}

#[test]
fn prop_tree_sum_equals_sequential_sum() {
    check_msg(
        "tree reduction ≡ sequential sum",
        60,
        |rng| {
            let nodes = 1 + rng.below(40);
            let dim = 1 + rng.below(30);
            let vs: Vec<Vec<f64>> = (0..nodes)
                .map(|_| (0..dim).map(|_| rng.normal() * 10.0).collect())
                .collect();
            vs
        },
        |vs| {
            let tree = tree_sum(vs);
            for j in 0..tree.len() {
                let seq: f64 = vs.iter().map(|v| v[j]).sum();
                if (tree[j] - seq).abs() > 1e-9 * (1.0 + seq.abs()) {
                    return Err(format!("component {j}: {} vs {seq}", tree[j]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_partitions_are_disjoint_covers() {
    check(
        "partition is a disjoint cover",
        80,
        |rng| {
            let n = 1 + rng.below(500);
            let p = 1 + rng.below(n.min(32));
            let shuffled = rng.bernoulli(0.5);
            (n, p, shuffled, rng.next_u64())
        },
        |&(n, p, shuffled, seed)| {
            let part = if shuffled {
                Partition::shuffled(n, p, seed)
            } else {
                Partition::contiguous(n, p)
            };
            part.is_disjoint_cover(n) && part.n_nodes() == p
        },
    );
}

#[test]
fn prop_tilted_gradient_consistency() {
    // ∇f̂_p(wʳ) = gʳ for arbitrary shards, weights and claimed gradients
    check_msg(
        "∇f̂_p(wʳ) = gʳ",
        40,
        |rng| {
            let d = 2 + rng.below(30);
            let n = 1 + rng.below(60);
            let x = random_csr(rng, n, d, 6);
            let y: Vec<f64> = (0..n).map(|_| rng.sign()).collect();
            let w_r: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let g_r: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let lam = rng.range(1e-4, 2.0);
            let loss = ALL_LOSSES[rng.below(3)];
            (x, y, w_r, g_r, lam, loss)
        },
        |(x, y, w_r, g_r, lam, loss)| {
            let d = w_r.len();
            let mut grad_lp = vec![0.0; d];
            shard_loss_grad(x, y, w_r, *loss, &mut grad_lp, None);
            let approx = LocalApprox::new(x, y, *loss, *lam, w_r, g_r, &grad_lp);
            let mut g = vec![0.0; d];
            approx.grad(w_r, &mut g);
            let err = dense::max_abs_diff(&g, g_r);
            if err < 1e-9 {
                Ok(())
            } else {
                Err(format!("consistency error {err}"))
            }
        },
    );
}

#[test]
fn prop_line_search_satisfies_armijo_wolfe() {
    // the paper's conditions (3) + (4) hold at the accepted step for
    // random convex margin problems
    check_msg(
        "Armijo–Wolfe at accepted t",
        30,
        |rng| {
            let d = 3 + rng.below(15);
            let n = 5 + rng.below(80);
            let x = random_csr(rng, n, d, 5);
            let y: Vec<f64> = (0..n).map(|_| rng.sign()).collect();
            let w: Vec<f64> = (0..d).map(|_| rng.normal() * 0.3).collect();
            let lam = rng.range(0.01, 1.0);
            let loss = ALL_LOSSES[rng.below(3)];
            (x, y, w, lam, loss)
        },
        |(x, y, w, lam, loss)| {
            let d_dim = w.len();
            let n = y.len();
            // steepest descent direction
            let mut g = vec![0.0; d_dim];
            shard_loss_grad(x, y, w, *loss, &mut g, None);
            dense::axpy(*lam, w, &mut g);
            let dir: Vec<f64> = g.iter().map(|v| -v).collect();
            if dense::norm(&dir) < 1e-12 {
                return Ok(()); // already optimal
            }
            let mut z = vec![0.0; n];
            let mut dz = vec![0.0; n];
            x.matvec(w, &mut z);
            x.matvec(&dir, &mut dz);
            let phi = MarginPhi { z: &z, dz: &dz, y, loss: *loss };
            let lamp = PhiLambda::new(*lam, w, &dir);
            let params = WolfeParams::default();
            let eval = |t: f64| {
                let (a, b) = phi.partial(t);
                lamp.compose(t, a, b)
            };
            let res = strong_wolfe(eval, &params)
                .map_err(|e| format!("line search failed: {e}"))?;
            let (phi0, dphi0) = eval(0.0);
            let armijo =
                res.phi_t <= phi0 + params.alpha * res.t * dphi0 + 1e-12;
            let wolfe = res.dphi_t >= params.beta * dphi0 - 1e-12;
            if !armijo {
                return Err(format!("Armijo violated at t={}", res.t));
            }
            if res.satisfied && !wolfe {
                return Err(format!("Wolfe violated at t={}", res.t));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_safeguarded_combination_is_descent_direction() {
    // after step 6 + step 7, dʳ·gʳ < 0 for any shard directions
    check_msg(
        "safeguarded average is descent",
        50,
        |rng| {
            let d = 2 + rng.below(20);
            let p = 1 + rng.below(10);
            let g: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let dirs: Vec<Vec<f64>> = (0..p)
                .map(|_| (0..d).map(|_| rng.normal() * 3.0).collect())
                .collect();
            (g, dirs)
        },
        |(g, dirs)| {
            if dense::norm(g) < 1e-12 {
                return Ok(());
            }
            let mut dirs = dirs.clone();
            Safeguard::default().apply(g, &mut dirs);
            // simple average
            let d_dim = g.len();
            let mut avg = vec![0.0; d_dim];
            for dp in &dirs {
                dense::axpy(1.0 / dirs.len() as f64, dp, &mut avg);
            }
            let dot = dense::dot(&avg, g);
            if dot < 0.0 {
                Ok(())
            } else {
                Err(format!("dʳ·g = {dot} ≥ 0"))
            }
        },
    );
}

#[test]
fn prop_svrg_descends_fhat_from_wr() {
    // descent property behind step 6's practical reading:
    // f̂_p(w_p) < f̂_p(wʳ) (then d_p is a descent direction of f)
    check_msg(
        "SVRG descends the tilted objective",
        15,
        |rng| {
            let d = 5 + rng.below(20);
            let n = 40 + rng.below(100);
            let x = random_csr(rng, n, d, 6);
            let y: Vec<f64> = (0..n).map(|_| rng.sign()).collect();
            let w_r: Vec<f64> = (0..d).map(|_| rng.normal() * 0.2).collect();
            let g2: Vec<f64> = (0..d).map(|_| rng.normal() * 0.5).collect();
            let lam = rng.range(0.05, 1.0);
            let seed = rng.next_u64();
            (x, y, w_r, g2, lam, seed)
        },
        |(x, y, w_r, g2, lam, seed)| {
            let d = w_r.len();
            let loss = LossKind::Logistic;
            let mut grad_lp = vec![0.0; d];
            shard_loss_grad(x, y, w_r, loss, &mut grad_lp, None);
            // plausible global gradient: local + perturbation
            let mut g_r = grad_lp.clone();
            dense::axpy(*lam, w_r, &mut g_r);
            dense::axpy(1.0, g2, &mut g_r);
            let approx = LocalApprox::new(x, y, loss, *lam, w_r, &g_r, &grad_lp);
            let (w_p, _) = svrg_epochs(
                &approx,
                w_r,
                &SvrgParams { epochs: 2, batch: 16, lr: None, seed: *seed },
            );
            let before = approx.value(w_r);
            let after = approx.value(&w_p);
            if after < before {
                Ok(())
            } else {
                Err(format!("f̂ went {before} → {after}"))
            }
        },
    );
}

#[test]
fn prop_csr_matvec_roundtrip_vs_dense() {
    check_msg(
        "CSR matvec/tmatvec vs dense",
        40,
        |rng| {
            let n = 1 + rng.below(40);
            let d = 1 + rng.below(30);
            let x = random_csr(rng, n, d, 5);
            let w: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let r: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (x, w, r)
        },
        |(x, w, r)| {
            let n = x.n_rows();
            let d = x.n_cols;
            let dense_x = x.to_dense();
            let mut z = vec![0.0; n];
            x.matvec(w, &mut z);
            for i in 0..n {
                let want: f64 =
                    dense_x[i].iter().zip(w).map(|(a, b)| a * b).sum();
                if (z[i] - want).abs() > 1e-9 {
                    return Err(format!("matvec row {i}"));
                }
            }
            let mut g = vec![0.0; d];
            x.tmatvec(r, &mut g);
            for j in 0..d {
                let want: f64 = (0..n).map(|i| dense_x[i][j] * r[i]).sum();
                if (g[j] - want).abs() > 1e-9 {
                    return Err(format!("tmatvec col {j}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_split_preserves_and_separates() {
    check(
        "train/test split partitions the dataset",
        20,
        |rng| {
            let n = 10 + rng.below(300);
            let cfg = SynthConfig {
                n_examples: n,
                n_features: 50,
                nnz_per_example: 5,
                ..SynthConfig::default()
            };
            (cfg.generate(rng.next_u64()), rng.range(0.2, 0.9), rng.next_u64())
        },
        |(data, frac, seed)| {
            let (tr, te) = data.split(*frac, *seed);
            tr.n_examples() + te.n_examples() == data.n_examples()
                && tr.nnz() + te.nnz() == data.nnz()
        },
    );
}
