//! Allocator-level audit of the compact master (`--features audit`):
//! arm the counting allocator's large-acquisition detector at d·8
//! bytes and prove a compact-master run materializes exactly one
//! full-d buffer (the `RunResult::w` expansion), while a dense-forced
//! run on identical data trips the detector every round — so the
//! static `no-dense-master` lint rule has a dynamic witness.
//!
//! The counters live in a process-global `#[global_allocator]`, so
//! every test here serializes on one mutex (cargo runs the tests of a
//! binary concurrently).

use psgd::algo::fs::MasterMode;
use psgd::audit;
use psgd::prelude::*;
use std::sync::{Mutex, MutexGuard};

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    // a panicking sibling must not cascade poison into unrelated tests
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Large enough that |U| ≪ d (≤ ~2k distinct columns drawn vs 200k
/// features) and an O(d) buffer (d·8 = 1.6 MB) dwarfs every legitimate
/// steady-state allocation.
const DIM: usize = 200_000;

fn big_sparse_cluster() -> Cluster {
    let data = psgd::data::synth::SynthConfig {
        n_examples: 400,
        n_features: DIM,
        nnz_per_example: 5,
        ..Default::default()
    }
    .generate(9);
    Cluster::partition(data, 4, CostModel::default())
}

fn fs_config(master: MasterMode) -> FsConfig {
    FsConfig { lam: 1.0, epochs: 1, master, ..Default::default() }
}

#[test]
fn compact_master_run_materializes_full_d_exactly_once() {
    let _g = serial();
    let mut cluster = big_sparse_cluster();
    assert!(cluster.prefer_compact_master());
    audit::set_large_alloc_threshold(DIM * 8);
    audit::reset_large_allocs();
    let fs = FsDriver::new(fs_config(MasterMode::Compact));
    let run = fs.run(&mut cluster, None, &StopRule::iters(3));
    let large = audit::large_alloc_count();
    audit::set_large_alloc_threshold(usize::MAX);
    assert!(run.f.is_finite());
    assert_eq!(run.w.len(), DIM);
    assert!(
        large <= 1,
        "compact-master run made {large} O(d)-sized heap acquisitions; \
         only the final RunResult::w expansion is sanctioned"
    );
}

#[test]
fn dense_master_run_trips_the_large_alloc_detector() {
    let _g = serial();
    let mut cluster = big_sparse_cluster();
    audit::set_large_alloc_threshold(DIM * 8);
    audit::reset_large_allocs();
    let fs = FsDriver::new(fs_config(MasterMode::Dense));
    let run = fs.run(&mut cluster, None, &StopRule::iters(3));
    let large = audit::large_alloc_count();
    audit::set_large_alloc_threshold(usize::MAX);
    assert!(run.f.is_finite());
    // the dense master pays at least one O(d) buffer per outer round
    // (the same counter the compact test holds at ≤ 1) — this is the
    // positive control proving the detector actually observes them
    assert!(
        large >= 3,
        "dense master should allocate O(d) every round, saw {large}"
    );
}

#[test]
fn counting_allocator_observes_every_acquisition_path() {
    let _g = serial();
    let watch = audit::AllocWatch::begin();
    let mut v: Vec<u64> = Vec::with_capacity(1024);
    v.extend(0..1024u64);
    let z = vec![0u8; 4096]; // the alloc_zeroed path vec![0.0; d] takes
    assert!(z.iter().all(|&b| b == 0));
    assert_eq!(v.len(), 1024);
    v.reserve(100_000); // realloc growth
    assert!(watch.allocations() >= 3, "saw {}", watch.allocations());
    assert!(watch.bytes() >= 1024 * 8 + 4096, "saw {}", watch.bytes());
    assert!(audit::max_single_alloc() >= 100_000 * 8);
    assert!(audit::alloc_count() > 0);
}
