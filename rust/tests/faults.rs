//! Fault-injection / elastic-membership acceptance suite.
//!
//! The contract the fault layer must keep:
//!
//! 1. **Zero-fault bit-identity** — installing the empty
//!    [`FaultPlan`] leaves both the synchronous and the async FS runs
//!    bit-identical (iterates, trace, ledger) to a run with no plan
//!    installed at all. The fault layer is structurally absent when
//!    the weather is clear: full-membership rounds delegate to the
//!    exact pre-fault code paths.
//! 2. **Seeded determinism** — the same seed replays the identical
//!    fault timeline (the [`FaultState::log`] of applied faults) and
//!    the bit-identical objective trace, run after run.
//! 3. **Crash + restart convergence** — a run that loses a node
//!    mid-flight and gets it back still reaches the synchronous
//!    suite's relative-gap tolerance, while the ledger records the
//!    crash, the rejoin re-base, and its recovery seconds.
//! 4. **No hangs at the edges** — quorum 1 with all-but-one node
//!    dead, every contribution lost on the wire, and a virtual-time
//!    crash landing mid-run each terminate through the partial
//!    quorum + safeguard fallback, never a deadlock or panic.
//! 5. **Link weather** (`--link-profile`/`--link-fault`) — the
//!    uniform profile + empty link plan are structurally inert
//!    (bit-identical to no link state); one link seed replays the
//!    identical weather; partitions drop nodes from the quorum like
//!    crashes, a master-isolating partition heals through the
//!    certified synchronous fallback, and retry/backoff time lands
//!    in the distinct `retry_seconds` counter — no link state can
//!    hang a round.

use psgd::algo::adapt::{Asynchrony, Quorum};
use psgd::algo::async_fs::{AsyncFsConfig, AsyncFsDriver};
use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::{Driver, StopRule};
use psgd::cluster::{
    Cluster, CostModel, FaultPlan, LinkFaultPlan, LinkProfile, NodeProfile,
};
use psgd::data::dataset::Dataset;
use psgd::data::synth::SynthConfig;
use psgd::loss::LossKind;
use psgd::metrics::trace::Trace;
use psgd::objective::RegularizedLoss;
use psgd::opt::tron::{self, TronParams};
use psgd::util::json;

/// Same sparse-regime data the async suite pins.
fn make_data(seed: u64) -> Dataset {
    SynthConfig {
        n_examples: 400,
        n_features: 2_000,
        nnz_per_example: 5,
        skew: 1.0,
        ..SynthConfig::default()
    }
    .generate(seed)
}

/// Modeled-time cluster: `CostModel::free()` zeroes the measured
/// compute share, so clocks — and therefore `Trigger::Time`
/// boundaries and ledger seconds — are bit-reproducible across runs.
fn make_cluster(nodes: usize, seed: u64) -> Cluster {
    let mut c = Cluster::partition(make_data(seed), nodes, CostModel::free());
    c.threads = 1;
    c
}

/// Default cost model: clocks actually advance, so `Trigger::Time`
/// thresholds fire and rejoin state transfer charges virtual seconds.
fn make_cluster_timed(nodes: usize, seed: u64) -> Cluster {
    let mut c =
        Cluster::partition(make_data(seed), nodes, CostModel::default());
    c.threads = 1;
    c
}

fn fs_config() -> FsConfig {
    FsConfig { lam: 0.5, epochs: 2, ..Default::default() }
}

fn async_config(staleness: usize, quorum: usize) -> AsyncFsConfig {
    AsyncFsConfig {
        fs: fs_config(),
        policy: Asynchrony::Bounded {
            tau: staleness,
            quorum: Quorum::AtLeast(quorum),
        },
        ..Default::default()
    }
}

/// Exact optimum of the stitched problem (the synchronous oracle).
fn f_star(cluster: &Cluster, loss: LossKind, lam: f64) -> f64 {
    let mut rows = Vec::new();
    let mut ys = Vec::new();
    for s in &cluster.shards {
        for i in 0..s.xl.n_rows() {
            rows.push(s.row_global(i));
            ys.push(s.y[i]);
        }
    }
    let x = psgd::linalg::Csr::from_rows(cluster.dim, &rows);
    let obj = RegularizedLoss { x: &x, y: &ys, loss, lam };
    tron::minimize(&obj, &vec![0.0; cluster.dim], &TronParams {
        eps: 1e-12,
        max_iter: 200,
        ..Default::default()
    })
    .f
}

/// Bitwise trace comparison: objective, pass accounting, simulated
/// seconds, and safeguard counts per outer iteration.
fn assert_traces_identical(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{what}: iteration counts");
    for (p, q) in a.points.iter().zip(&b.points) {
        assert_eq!(p.f, q.f, "{what}: objective diverged at iter {}", p.iter);
        assert_eq!(
            p.comm_passes, q.comm_passes,
            "{what}: pass accounting diverged at iter {}",
            p.iter
        );
        assert_eq!(
            p.seconds, q.seconds,
            "{what}: simulated seconds diverged at iter {}",
            p.iter
        );
        assert_eq!(
            p.safeguard_hits, q.safeguard_hits,
            "{what}: safeguard counts diverged at iter {}",
            p.iter
        );
    }
}

#[test]
fn empty_fault_plan_is_bit_identical_to_no_plan_sync_fs() {
    let nodes = 4;
    let mut bare = make_cluster(nodes, 2);
    let mut planned = make_cluster(nodes, 2);
    planned.set_fault_plan(FaultPlan::default());

    let run_bare =
        FsDriver::new(fs_config()).run(&mut bare, None, &StopRule::iters(8));
    let run_planned =
        FsDriver::new(fs_config()).run(&mut planned, None, &StopRule::iters(8));

    assert_eq!(run_bare.w, run_planned.w, "sync iterates diverged");
    assert_traces_identical(&run_bare.trace, &run_planned.trace, "sync FS");
    assert_eq!(bare.ledger, planned.ledger, "sync ledgers diverged");
    let faults = planned.faults.as_ref().expect("plan installed");
    assert!(faults.log.is_empty(), "empty plan applied a fault");
}

#[test]
fn empty_fault_plan_is_bit_identical_to_no_plan_async_fs() {
    let nodes = 4;
    let mut bare = make_cluster(nodes, 2);
    let mut planned = make_cluster(nodes, 2);
    // heterogeneous speeds exercise the member compute lanes too
    let profile = NodeProfile::with_straggler(nodes, 0, 3.0);
    bare.set_profile(profile.clone());
    planned.set_profile(profile);
    planned.set_fault_plan(FaultPlan::default());

    let run_bare = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut bare,
        None,
        &StopRule::iters(12),
    );
    let run_planned = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut planned,
        None,
        &StopRule::iters(12),
    );

    assert_eq!(run_bare.w, run_planned.w, "async iterates diverged");
    assert_traces_identical(&run_bare.trace, &run_planned.trace, "async FS");
    assert_eq!(bare.ledger, planned.ledger, "async ledgers diverged");
    assert!(!planned.ledger.has_fault_activity());
    assert!(planned
        .faults
        .as_ref()
        .expect("plan installed")
        .log
        .is_empty());
}

#[test]
fn same_seed_replays_identical_fault_timeline_and_trace() {
    let nodes = 5;
    let script =
        "crash:1@r2,restart:1@r5,degrade:2@r1:0.5x,flap:3:p=0.2,loss:p=0.15";
    let run = |seed: u64| {
        let mut cluster = make_cluster(nodes, 3);
        let mut plan = FaultPlan::parse(script, nodes).unwrap();
        plan.seed = seed;
        cluster.set_fault_plan(plan);
        let run = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
            &mut cluster,
            None,
            &StopRule::iters(20),
        );
        let log = cluster.faults.as_ref().unwrap().log.clone();
        (run, log, cluster.ledger.clone())
    };

    let (run_a, log_a, ledger_a) = run(9);
    let (run_b, log_b, ledger_b) = run(9);
    assert!(!log_a.is_empty(), "the chaos script never fired");
    assert_eq!(log_a, log_b, "fault timelines diverged under one seed");
    assert_eq!(run_a.w, run_b.w, "iterates diverged under one seed");
    assert_traces_identical(&run_a.trace, &run_b.trace, "seeded replay");
    assert_eq!(ledger_a, ledger_b, "ledgers diverged under one seed");

    // a different seed re-rolls the flap/loss coins: some divergence
    // in the applied-fault log is overwhelmingly likely at p=0.2/0.15
    let (_, log_c, _) = run(10);
    assert_ne!(log_a, log_c, "seed had no effect on the weather");
}

#[test]
fn crash_and_restart_converges_to_sync_tolerance() {
    let nodes = 5;
    let mut cluster = make_cluster_timed(nodes, 3);
    let cfg = fs_config();
    let fstar = f_star(&cluster, cfg.loss, cfg.lam);
    cluster.set_fault_plan(
        FaultPlan::parse("crash:1@r2,restart:1@r6", nodes).unwrap(),
    );

    let run = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut cluster,
        None,
        &StopRule::iters(60),
    );

    // same tolerance the synchronous and async suites pin
    let gap = (run.f - fstar) / fstar;
    assert!(gap < 1e-4, "crash+restart run stalled: gap={gap}");
    assert!(cluster.ledger.crash_events >= 1, "no crash recorded");
    assert!(cluster.ledger.rejoin_rebases >= 1, "no rejoin re-base recorded");
    assert!(
        cluster.ledger.recovery_seconds > 0.0,
        "rejoin state transfer charged no virtual time"
    );
    // the fault log carries the scripted pair in application order
    let log = &cluster.faults.as_ref().unwrap().log;
    assert!(log.iter().any(|f| f.what == "crash" && f.node == 1));
    assert!(log.iter().any(|f| f.what == "restart" && f.node == 1));
    // the engine timeline shows the membership events
    let events = cluster.engine.events();
    assert!(events.iter().any(|e| e.label == "fault_crash"));
    assert!(events.iter().any(|e| e.label == "fault_restart"));
    assert!(events.iter().any(|e| e.label == "rejoin_rebase"));
}

#[test]
fn quorum_one_with_all_but_one_node_dead_terminates() {
    let nodes = 4;
    let mut cluster = make_cluster(nodes, 5);
    cluster.set_fault_plan(
        FaultPlan::parse("crash:1@r1,crash:2@r1,crash:3@r1", nodes).unwrap(),
    );

    let run = AsyncFsDriver::new(async_config(1, 1)).run(
        &mut cluster,
        None,
        &StopRule::iters(10),
    );

    assert_eq!(cluster.ledger.crash_events, 3, "all three crashes apply");
    assert_eq!(cluster.alive_nodes(), vec![0], "one survivor");
    assert!(run.f.is_finite(), "sole-survivor run produced a non-finite f");
    // the surviving shard's problem still descends through the
    // safeguarded rounds
    let pts = &run.trace.points;
    assert!(pts.last().unwrap().f < pts[0].f, "failed to descend");
}

#[test]
fn total_wire_loss_routes_every_round_through_the_fallback() {
    // loss:p=1 drops every contribution even after the retry: the
    // quorum is empty each round — the same empty-contribution path an
    // all-over-stale lane set hits — and each round must terminate
    // through the certified synchronous fallback, never a hang.
    let nodes = 4;
    let mut cluster = make_cluster(nodes, 7);
    cluster.set_fault_plan(FaultPlan::parse("loss:p=1", nodes).unwrap());

    let run = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut cluster,
        None,
        &StopRule::iters(8),
    );

    assert!(cluster.ledger.lost_messages >= nodes, "wire never dropped");
    assert!(
        cluster.ledger.fallback_rounds >= 1,
        "empty quorum failed to fall back: {}",
        cluster.ledger.staleness_profile()
    );
    // the safeguard invariant holds: every committed direction came
    // from the synchronous fallback, so descent is monotone
    for k in 1..run.trace.points.len() {
        assert!(
            run.trace.points[k].f <= run.trace.points[k - 1].f + 1e-10,
            "f increased at iter {k} despite certified fallbacks"
        );
    }
}

#[test]
fn time_triggered_crash_mid_run_terminates_and_recovers() {
    // virtual-time triggers quantize to the first round boundary at or
    // past T — a crash "landing mid-allreduce" takes effect before the
    // next reduce begins, so no hop is ever half-charged
    let nodes = 4;
    let mut cluster = make_cluster_timed(nodes, 11);
    cluster.set_fault_plan(
        FaultPlan::parse("crash:2@1e-9s,restart:2@r5", nodes).unwrap(),
    );

    let run = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut cluster,
        None,
        &StopRule::iters(30),
    );

    assert_eq!(cluster.ledger.crash_events, 1);
    assert_eq!(cluster.ledger.rejoin_rebases, 1);
    assert_eq!(cluster.alive_nodes().len(), nodes, "node 2 never rejoined");
    assert!(run.f.is_finite());
    let log = &cluster.faults.as_ref().unwrap().log;
    // the time trigger fired after round 0's work moved the clock
    let crash = log.iter().find(|f| f.what == "crash").unwrap();
    assert!(crash.round >= 1, "time trigger fired before any clock moved");
}

#[test]
fn flap_and_degrade_weather_converges_and_is_accounted() {
    let nodes = 5;
    let mut cluster = make_cluster(nodes, 13);
    let cfg = fs_config();
    let fstar = f_star(&cluster, cfg.loss, cfg.lam);
    cluster.set_fault_plan(
        FaultPlan::parse("degrade:1@r1:0.25x,flap:3:p=0.3,loss:p=0.1", nodes)
            .unwrap(),
    );

    let run = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut cluster,
        None,
        &StopRule::iters(60),
    );

    let gap = (run.f - fstar) / fstar;
    assert!(gap < 1e-4, "fleet weather stalled the run: gap={gap}");
    assert_eq!(cluster.ledger.degrade_events, 1);
    assert!(cluster.ledger.flap_events >= 1, "p=0.3 flap never fired");
    assert!(cluster.ledger.has_fault_activity());
    assert!(!cluster.ledger.fault_profile().is_empty());
}

#[test]
fn timeline_json_schema_carries_the_resilience_block() {
    let nodes = 4;
    let mut cluster = make_cluster(nodes, 17);
    cluster.set_fault_plan(
        FaultPlan::parse("crash:1@r2,restart:1@r5,loss:p=0.2", nodes).unwrap(),
    );
    let _ = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut cluster,
        None,
        &StopRule::iters(12),
    );

    // round-trip through the serialized form: the schema the chaos CI
    // job archives must parse back and carry every resilience field
    let text = cluster.timeline_json().to_json(0);
    let v = json::parse(&text).expect("timeline JSON must parse");
    let r = v.get("resilience").expect("resilience block missing");
    for key in [
        "async_rounds",
        "fallback_rounds",
        "crash_events",
        "rejoin_rebases",
        "lost_messages",
        "retry_rounds",
        "degrade_events",
        "flap_events",
        "recovery_seconds",
    ] {
        assert!(r.get(key).is_some(), "resilience field {key} missing");
    }
    assert_eq!(
        r.get("crash_events").and_then(|x| x.as_usize()),
        Some(1),
        "{text}"
    );
    assert_eq!(
        r.get("rejoin_rebases").and_then(|x| x.as_usize()),
        Some(1)
    );
    let alive = match r.get("alive") {
        Some(json::Value::Arr(a)) => a.len(),
        other => panic!("alive roster missing or not an array: {other:?}"),
    };
    assert_eq!(alive, nodes);
    let hist = r.get("staleness_hist").expect("staleness_hist missing");
    assert!(matches!(hist, json::Value::Arr(_)));
}

#[test]
fn uniform_link_profile_and_empty_plan_are_bit_identical() {
    // the PR-9 equivalence gate: a uniform profile plus the empty
    // link-fault plan must leave both drivers byte-for-byte on the
    // pre-link-weather code paths — iterates, trace, and full ledger
    let nodes = 4;
    let mut bare = make_cluster(nodes, 2);
    let mut linked = make_cluster(nodes, 2);
    linked.set_link_profile(LinkProfile::uniform(nodes));
    linked.set_link_fault_plan(LinkFaultPlan::default());

    let run_bare =
        FsDriver::new(fs_config()).run(&mut bare, None, &StopRule::iters(8));
    let run_linked = FsDriver::new(fs_config()).run(
        &mut linked,
        None,
        &StopRule::iters(8),
    );
    assert_eq!(run_bare.w, run_linked.w, "sync iterates diverged");
    assert_traces_identical(&run_bare.trace, &run_linked.trace, "sync FS");
    assert_eq!(bare.ledger, linked.ledger, "sync ledgers diverged");

    let mut bare = make_cluster(nodes, 2);
    let mut linked = make_cluster(nodes, 2);
    linked.set_link_profile(LinkProfile::uniform(nodes));
    linked.set_link_fault_plan(LinkFaultPlan::default());
    let run_bare = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut bare,
        None,
        &StopRule::iters(12),
    );
    let run_linked = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut linked,
        None,
        &StopRule::iters(12),
    );
    assert_eq!(run_bare.w, run_linked.w, "async iterates diverged");
    assert_traces_identical(&run_bare.trace, &run_linked.trace, "async FS");
    assert_eq!(bare.ledger, linked.ledger, "async ledgers diverged");
    assert_eq!(linked.link_log_len(), 0, "empty plan applied link weather");
}

#[test]
fn same_link_seed_replays_identical_weather_and_trace() {
    let nodes = 5;
    let script = "congest:p=0.3:4x,flap:p=0.4,part:3+4@r4..r7,timeout:0.001";
    let run = |seed: u64| {
        let mut cluster = make_cluster(nodes, 3);
        let mut plan = LinkFaultPlan::parse(script, nodes).unwrap();
        plan.seed = seed;
        cluster.set_link_fault_plan(plan);
        let run = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
            &mut cluster,
            None,
            &StopRule::iters(20),
        );
        let log: Vec<_> = (0..cluster.link_log_len())
            .map(|i| cluster.link_log_entry(i).unwrap())
            .collect();
        (run, log, cluster.ledger.clone())
    };

    let (run_a, log_a, ledger_a) = run(9);
    let (run_b, log_b, ledger_b) = run(9);
    assert!(!log_a.is_empty(), "the partition never fired");
    assert!(
        ledger_a.link_retries > 0,
        "p=0.4 flaps never cost a retry: {}",
        ledger_a.fault_profile()
    );
    assert!(ledger_a.retry_seconds > 0.0, "retries charged no backoff");
    assert_eq!(log_a, log_b, "link timelines diverged under one seed");
    assert_eq!(run_a.w, run_b.w, "iterates diverged under one seed");
    assert_traces_identical(&run_a.trace, &run_b.trace, "link replay");
    assert_eq!(ledger_a, ledger_b, "ledgers diverged under one seed");

    // a different seed re-rolls the congest/flap coins
    let (_, _, ledger_c) = run(10);
    assert_ne!(
        ledger_a, ledger_c,
        "link seed had no effect on the weather"
    );
}

#[test]
fn master_isolating_partition_heals_through_the_fallback() {
    // part:1+2+3 strands the master with no peers: the quorum shrinks
    // to the surviving member set like a crash, and the heal round
    // must route through the certified synchronous fallback
    // ("partition-heal") — never a hang, never a stale commit
    let nodes = 4;
    let mut cluster = make_cluster(nodes, 5);
    cluster.set_link_fault_plan(
        LinkFaultPlan::parse("part:1+2+3@r2..r5", nodes).unwrap(),
    );

    let run = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut cluster,
        None,
        &StopRule::iters(12),
    );

    assert!(run.f.is_finite(), "master-isolating partition hung the run");
    assert_eq!(cluster.ledger.partition_events, 1, "cut never applied");
    // the heal re-bases every partitioned-away node ...
    assert!(
        cluster.ledger.rejoin_rebases >= 3,
        "healed nodes never re-based: {}",
        cluster.ledger.fault_profile()
    );
    // ... and the resync round fell back to the synchronous barrier
    assert!(
        cluster.ledger.fallback_rounds >= 1,
        "partition heal skipped the certified fallback"
    );
    // descent survives the weather
    let pts = &run.trace.points;
    assert!(pts.last().unwrap().f < pts[0].f, "failed to descend");
    // the link log replays the cut and the heal on its own watermark
    let entries: Vec<_> = (0..cluster.link_log_len())
        .map(|i| cluster.link_log_entry(i).unwrap())
        .collect();
    assert!(entries.iter().any(|e| e.2 == "partition"));
    assert!(entries.iter().any(|e| e.2 == "heal"));
    assert_eq!(cluster.fault_log_len(), 0, "node-fault log stayed clean");
}

#[test]
fn partition_longer_than_tau_bounds_staleness_on_heal() {
    // a partition lasting past τ rounds must not let pre-partition
    // hybrids re-enter the quorum: staleness stays ≤ τ and the healed
    // nodes re-base onto the current iterate instead
    let nodes = 4;
    let tau = 2;
    let mut cluster = make_cluster(nodes, 7);
    cluster.set_link_fault_plan(
        LinkFaultPlan::parse("part:2+3@r2..r8", nodes).unwrap(),
    );

    let run = AsyncFsDriver::new(async_config(tau, 2)).run(
        &mut cluster,
        None,
        &StopRule::iters(16),
    );

    assert!(run.f.is_finite());
    assert!(
        cluster.ledger.staleness_hist.len() <= tau + 1,
        "a hybrid older than τ={tau} entered the quorum: hist {:?}",
        cluster.ledger.staleness_hist
    );
    assert!(cluster.ledger.rejoin_rebases >= 2, "heal never re-based");
}

#[test]
fn total_partition_with_total_wire_loss_terminates() {
    // the worst corner: every peer partitioned away AND every
    // surviving contribution lost on the wire — the empty quorum must
    // route through the fallback each round, with monotone descent
    let nodes = 4;
    let mut cluster = make_cluster(nodes, 7);
    cluster.set_fault_plan(FaultPlan::parse("loss:p=1", nodes).unwrap());
    cluster.set_link_fault_plan(
        LinkFaultPlan::parse("part:1+2+3@r1..r6", nodes).unwrap(),
    );

    let run = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut cluster,
        None,
        &StopRule::iters(8),
    );

    assert!(run.f.is_finite(), "total partition hung the run");
    assert!(
        cluster.ledger.fallback_rounds >= 1,
        "empty quorum failed to fall back"
    );
    for k in 1..run.trace.points.len() {
        assert!(
            run.trace.points[k].f <= run.trace.points[k - 1].f + 1e-10,
            "f increased at iter {k} despite certified fallbacks"
        );
    }
}

#[test]
fn heterogeneous_links_stretch_time_and_keep_the_maths() {
    // a slow uplink changes only the virtual clock: iterates are
    // bit-identical, makespan strictly grows, and retry/backoff time
    // stays out of comm seconds. Modeled time (compute_scale 0) keeps
    // the clocks — and therefore the quorum arrival order — exactly
    // reproducible while comm still costs virtual seconds.
    let nodes = 4;
    let modeled = CostModel { compute_scale: 0.0, ..CostModel::default() };
    let mut base = Cluster::partition(make_data(3), nodes, modeled);
    base.threads = 1;
    let mut skewed = Cluster::partition(make_data(3), nodes, modeled);
    skewed.threads = 1;
    skewed.set_link_profile(
        LinkProfile::parse("uplink:1:3x,level:1:2x", nodes).unwrap(),
    );

    let run_base = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut base,
        None,
        &StopRule::iters(10),
    );
    let run_skewed = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut skewed,
        None,
        &StopRule::iters(10),
    );

    assert_eq!(run_base.w, run_skewed.w, "link speeds moved the maths");
    assert!(
        skewed.ledger.comm_seconds > base.ledger.comm_seconds,
        "slow links charged no extra comm time"
    );
    assert_eq!(skewed.ledger.retry_seconds, 0.0, "no plan, no retries");
    assert_eq!(
        skewed.ledger.comm_passes, base.ledger.comm_passes,
        "profile changed pass accounting"
    );
}

#[test]
fn timeline_json_carries_the_link_events_block() {
    let nodes = 4;
    let mut cluster = make_cluster(nodes, 17);
    cluster.set_link_fault_plan(
        LinkFaultPlan::parse(
            "flap:p=0.5,congest:p=0.3,part:3@r2..r4,timeout:0.001",
            nodes,
        )
        .unwrap(),
    );
    let _ = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
        &mut cluster,
        None,
        &StopRule::iters(10),
    );

    let text = cluster.timeline_json().to_json(0);
    let v = json::parse(&text).expect("timeline JSON must parse");
    let r = v.get("resilience").expect("resilience block missing");
    assert!(r.get("retry_seconds").is_some(), "retry_seconds missing");
    let le = v.get("link_events").expect("link_events block missing");
    for key in [
        "link_retries",
        "reroutes",
        "congested_hops",
        "partition_events",
        "retry_seconds",
    ] {
        assert!(le.get(key).is_some(), "link_events field {key} missing");
    }
    assert_eq!(
        le.get("partition_events").and_then(|x| x.as_usize()),
        Some(1),
        "{text}"
    );
    assert!(
        le.get("link_retries").and_then(|x| x.as_usize()).unwrap_or(0) > 0,
        "p=0.5 flaps never retried: {text}"
    );
}

#[test]
fn seeded_fleet_weather_matrix_never_hangs() {
    // the chaos-bench matrix in miniature: three seeds of generated
    // weather, each must terminate with a finite objective and a
    // replayable fault log
    for seed in [1u64, 2, 3] {
        let nodes = 5;
        let mut cluster = make_cluster(nodes, 19);
        cluster.set_fault_plan(FaultPlan::seeded(nodes, seed));
        let run = AsyncFsDriver::new(async_config(2, nodes - 1)).run(
            &mut cluster,
            None,
            &StopRule::iters(25),
        );
        assert!(run.f.is_finite(), "seed {seed}: non-finite objective");
        assert!(
            cluster.ledger.has_fault_activity(),
            "seed {seed}: generated weather was a no-op"
        );
        assert!(
            cluster.ledger.crash_events >= 1
                && cluster.ledger.rejoin_rebases >= 1,
            "seed {seed}: generator must crash and restart a victim"
        );
    }
}
