//! Union-support compact-master regression suite.
//!
//! The contract: the compact master is a *representation* change, not
//! an algorithm change. Running the entire outer loop in length-|U|
//! buffers (U = ⋃_p support_p) must reproduce the dense master's run
//! ε-identically — objective trace, gradient norms, safeguard
//! decisions, pass accounting and the final iterate — across shard
//! shapes (skewed, all-dense, 1-nnz, overlapping supports), all five
//! inner solvers, and the bounded-staleness async driver. Wire bytes
//! and modeled seconds are allowed to differ (the compact regime
//! ships O(|U|) broadcasts — that is the point); the maths is not.
//!
//! Async note: with a full quorum the round composition is
//! deterministic for any τ (every solve is fresh by the deadline), so
//! τ ∈ {0, 2} pin trace equality exactly — τ = 2 still exercises the
//! O(τ·|U|) master reference ring. Partial-quorum staleness depends
//! on *measured* solve seconds (which differ run to run), so the
//! stale re-basing path is exercised on the compact master alone
//! against the synchronous oracle's tolerance instead.

use psgd::algo::adapt::{Asynchrony, Quorum};
use psgd::algo::async_fs::{AsyncFsConfig, AsyncFsDriver};
use psgd::algo::fs::{FsConfig, FsDriver, InnerSolver, MasterMode};
use psgd::algo::{Driver, RunResult, StopRule};
use psgd::cluster::{Cluster, CostModel, NodeProfile};
use psgd::data::dataset::Dataset;
use psgd::data::synth::SynthConfig;
use psgd::linalg::{dense, Csr};
use psgd::loss::LossKind;
use psgd::objective::RegularizedLoss;
use psgd::opt::tron::{self, TronParams};

/// High-dimensional sparse-regime data: |U| ≪ d, overlapping shard
/// supports (the Zipf head features appear in every shard).
fn sparse_data(seed: u64) -> Dataset {
    SynthConfig {
        n_examples: 400,
        n_features: 2_000,
        nnz_per_example: 5,
        skew: 1.0,
        ..SynthConfig::default()
    }
    .generate(seed)
}

/// Every column populated: support = U = all of d (the degenerate
/// frame where compact == dense up to the identity index map).
fn all_dense_data(seed: u64) -> Dataset {
    SynthConfig {
        n_examples: 200,
        n_features: 25,
        nnz_per_example: 30,
        ..SynthConfig::default()
    }
    .generate(seed)
}

/// One nonzero per example over a much larger column space.
fn one_nnz_data(seed: u64) -> Dataset {
    SynthConfig {
        n_examples: 300,
        n_features: 1_500,
        nnz_per_example: 1,
        ..SynthConfig::default()
    }
    .generate(seed)
}

fn fs_cfg(inner: InnerSolver, master: MasterMode) -> FsConfig {
    FsConfig {
        lam: 0.5,
        epochs: 2,
        inner,
        lr: if inner == InnerSolver::Sgd { Some(0.01) } else { None },
        master,
        ..Default::default()
    }
}

/// ε-identity of two runs: trajectory, safeguard decisions, pass
/// accounting and final iterate (bytes/seconds deliberately excluded).
///
/// `tol`: in the sparse regime both masters ride the same sparse wire
/// and every sum runs in the same coordinate order, so the runs are
/// near-bitwise (1e-9). Forcing the compact master on *dense* shards
/// crosses the wire divide — step 7 associates its sums differently
/// (coefficient sums + corr reduce vs per-node dense parts), an
/// ulp-level difference the line search can amplify — so that case
/// pins ε-identity at 1e-6 instead.
fn assert_runs_match(d: &RunResult, c: &RunResult, tag: &str, tol: f64) {
    assert_eq!(
        d.trace.points.len(),
        c.trace.points.len(),
        "{tag}: outer iteration counts diverged"
    );
    for (pd, pc) in d.trace.points.iter().zip(&c.trace.points) {
        let k = pd.iter;
        assert!(
            (pd.f - pc.f).abs() <= tol * (1.0 + pd.f.abs()),
            "{tag}: f diverged at iter {k}: {} vs {}",
            pd.f,
            pc.f
        );
        assert!(
            (pd.gnorm - pc.gnorm).abs() <= tol * (1.0 + pd.gnorm),
            "{tag}: ‖g‖ diverged at iter {k}: {} vs {}",
            pd.gnorm,
            pc.gnorm
        );
        assert_eq!(
            pd.safeguard_hits, pc.safeguard_hits,
            "{tag}: safeguard decisions diverged at iter {k}"
        );
        assert_eq!(
            pd.comm_passes, pc.comm_passes,
            "{tag}: pass accounting diverged at iter {k}"
        );
        assert!(
            (pd.auprc.is_nan() && pc.auprc.is_nan())
                || (pd.auprc - pc.auprc).abs() <= tol.max(1e-9),
            "{tag}: AUPRC diverged at iter {k}: {} vs {}",
            pd.auprc,
            pc.auprc
        );
    }
    assert_eq!(d.w.len(), c.w.len(), "{tag}: iterate dims diverged");
    let diff = dense::max_abs_diff(&d.w, &c.w);
    assert!(diff <= tol, "{tag}: final iterates diverged by {diff}");
}

/// Run the same config under both forced masters on forked clusters.
fn run_both(
    data: &Dataset,
    nodes: usize,
    inner: InnerSolver,
    iters: usize,
    asynchronous: Option<(usize, usize)>, // (τ, quorum)
) -> (RunResult, RunResult) {
    let (train, test) = data.split(0.85, 3);
    let c0 = Cluster::partition(train, nodes, CostModel::default());
    let mut out = Vec::new();
    for master in [MasterMode::Dense, MasterMode::Compact] {
        let mut cluster = c0.fork_fresh();
        cluster.threads = 1;
        let cfg = fs_cfg(inner, master);
        let run = match asynchronous {
            None => FsDriver::new(cfg).run(
                &mut cluster,
                Some(&test),
                &StopRule::iters(iters),
            ),
            Some((tau, quorum)) => AsyncFsDriver::new(AsyncFsConfig {
                fs: cfg,
                policy: Asynchrony::Bounded {
                    tau,
                    quorum: Quorum::AtLeast(quorum),
                },
                ..Default::default()
            })
            .run(&mut cluster, Some(&test), &StopRule::iters(iters)),
        };
        out.push(run);
    }
    let compact = out.pop().unwrap();
    let dense_run = out.pop().unwrap();
    (dense_run, compact)
}

#[test]
fn compact_master_matches_dense_for_all_solvers_on_sparse_shards() {
    for inner in [
        InnerSolver::Svrg,
        InnerSolver::Sag,
        InnerSolver::Sgd,
        InnerSolver::Lbfgs,
        InnerSolver::Tron,
    ] {
        let data = sparse_data(2);
        let (d, c) = run_both(&data, 4, inner, 8, None);
        assert_runs_match(&d, &c, &format!("sparse/{inner:?}"), 1e-9);
    }
}

#[test]
fn compact_master_matches_dense_across_shard_shapes() {
    // all-dense (U = every column — the gate would never pick compact,
    // and the dense master rides the dense wire there: cross-wire
    // tolerance), 1-nnz, and skewed/overlapping (same-wire: tight)
    for (data, tag, tol) in [
        (all_dense_data(5), "all-dense", 1e-6),
        (one_nnz_data(7), "one-nnz", 1e-9),
        (sparse_data(11), "overlapping", 1e-9),
    ] {
        let (d, c) = run_both(&data, 3, InnerSolver::Svrg, 6, None);
        assert_runs_match(&d, &c, tag, tol);
    }
}

#[test]
fn compact_master_matches_dense_under_async_quorum() {
    // full quorum keeps the round composition deterministic for any τ
    // (see module docs); τ = 2 exercises the τ+1-deep reference ring
    let nodes = 4;
    for tau in [0usize, 2] {
        let data = sparse_data(13);
        let (d, c) =
            run_both(&data, nodes, InnerSolver::Svrg, 8, Some((tau, nodes)));
        assert_runs_match(&d, &c, &format!("async-τ{tau}"), 1e-9);
    }
}

#[test]
fn compact_async_with_stale_quorum_still_converges() {
    // the nondeterministic regime (partial quorum, straggler, real
    // measured solve seconds): the compact master's stale re-basing —
    // O(τ·|U|) ring, U-position corrections — must keep the paper's
    // convergence guarantee, exactly as the dense suite pins it
    let nodes = 5;
    let data = sparse_data(17);
    let mut cluster = Cluster::partition(data, nodes, CostModel::default());
    cluster.threads = 1;
    cluster.set_profile(NodeProfile::with_straggler(nodes, 0, 3.0));
    assert!(cluster.prefer_compact_master());

    // oracle on the stitched problem
    let mut rows = Vec::new();
    let mut ys = Vec::new();
    for s in &cluster.shards {
        for i in 0..s.xl.n_rows() {
            rows.push(s.row_global(i));
            ys.push(s.y[i]);
        }
    }
    let x = Csr::from_rows(cluster.dim, &rows);
    let obj =
        RegularizedLoss { x: &x, y: &ys, loss: LossKind::Logistic, lam: 0.5 };
    let w0 = vec![0.0; cluster.dim];
    let fstar = tron::minimize(&obj, &w0, &TronParams {
        eps: 1e-12,
        max_iter: 200,
        ..Default::default()
    })
    .f;

    let run = AsyncFsDriver::new(AsyncFsConfig {
        fs: fs_cfg(InnerSolver::Svrg, MasterMode::Compact),
        policy: Asynchrony::Bounded {
            tau: 2,
            quorum: Quorum::AtLeast(nodes - 1),
        },
        ..Default::default()
    })
    .run(&mut cluster, None, &StopRule::iters(60));

    let gap = (run.f - fstar) / fstar;
    assert!(gap < 1e-4, "compact async gap {gap}");
    for k in 1..run.trace.points.len() {
        assert!(
            run.trace.points[k].f <= run.trace.points[k - 1].f + 1e-10,
            "f increased at iter {k}"
        );
    }
    assert!(
        cluster.ledger.staleness_hist.len() <= 3,
        "staleness bound violated: {:?}",
        cluster.ledger.staleness_hist
    );
}

#[test]
fn features_outside_union_support_stay_exactly_zero() {
    let data = sparse_data(19);
    let dim = data.n_features();
    let cluster = Cluster::partition(data, 4, CostModel::default());
    assert!(
        cluster.prefer_compact_master(),
        "union density {} should gate compact on",
        cluster.union_density()
    );
    // there must be columns outside U for this test to mean anything
    assert!(cluster.umap.len() < dim);
    let mut c = cluster.fork_fresh();
    let run = FsDriver::new(fs_cfg(InnerSolver::Svrg, MasterMode::Auto))
        .run(&mut c, None, &StopRule::iters(6));
    assert_eq!(run.w.len(), dim, "RunResult::w materializes full d");
    let mut in_u = vec![false; dim];
    for &col in &c.umap.support {
        in_u[col as usize] = true;
    }
    let mut outside = 0usize;
    for (j, &wj) in run.w.iter().enumerate() {
        if !in_u[j] {
            outside += 1;
            assert!(
                wj == 0.0,
                "feature {j} outside U moved to {wj} — the compact \
                 master must keep it exactly 0.0"
            );
        }
    }
    assert!(outside > 0, "no feature outside U — test is vacuous");
    // and the run actually optimized something
    let pts = &run.trace.points;
    assert!(pts.last().unwrap().f < pts[0].f);
}
