//! Empirical checks of the paper's theory on mid-size problems:
//! Theorem 1 (global linear rate), the safeguard probability behaviour
//! behind Theorem 2, and the Figure-1 orderings (FS beats SQM/Hybrid on
//! communication passes; the gap narrows as nodes increase).

use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::hybrid::{HybridConfig, HybridDriver};
use psgd::algo::param_mix::{ParamMixConfig, ParamMixDriver};
use psgd::algo::sqm::{SqmConfig, SqmDriver};
use psgd::algo::{Driver, StopRule};
use psgd::cluster::{Cluster, CostModel};
use psgd::data::dataset::Dataset;
use psgd::data::synth::SynthConfig;
use psgd::loss::LossKind;

const LAM: f64 = 0.5;

fn data(seed: u64) -> Dataset {
    SynthConfig {
        n_examples: 1_000,
        n_features: 120,
        nnz_per_example: 10,
        skew: 1.0,
        ..SynthConfig::default()
    }
    .generate(seed)
}

fn cluster(d: &Dataset, nodes: usize) -> Cluster {
    Cluster::partition(d.clone(), nodes, CostModel::free())
}

/// High-accuracy reference optimum via distributed TRON.
fn f_star(d: &Dataset, loss: LossKind) -> f64 {
    let mut c = cluster(d, 1);
    let mut cfg = SqmConfig { loss, lam: LAM, ..Default::default() };
    cfg.tron.eps = 1e-13;
    cfg.tron.max_iter = 300;
    SqmDriver::new(cfg).run(&mut c, None, &StopRule::iters(300)).f
}

#[test]
fn theorem1_global_linear_rate_across_losses() {
    // (f(w^{r+1}) − f*) ≤ δ (f(w^r) − f*) with a uniform δ < 1
    for loss in [LossKind::Logistic, LossKind::SquaredHinge, LossKind::LeastSquares] {
        let d = data(1);
        let fstar = f_star(&d, loss);
        let mut c = cluster(&d, 5);
        let run = FsDriver::new(FsConfig {
            loss,
            lam: LAM,
            epochs: 2,
            ..Default::default()
        })
        .run(&mut c, None, &StopRule::iters(20));
        let gaps: Vec<f64> = run
            .trace
            .points
            .iter()
            .map(|p| p.f - fstar)
            .take_while(|g| *g > 1e-11)
            .collect();
        assert!(gaps.len() >= 4, "{loss:?}: trace too short ({gaps:?})");
        let mut worst = 0.0f64;
        for k in 1..gaps.len() {
            worst = worst.max(gaps[k] / gaps[k - 1]);
        }
        assert!(
            worst < 1.0,
            "{loss:?}: worst contraction ratio {worst} (gaps {gaps:?})"
        );
    }
}

#[test]
fn fs_beats_sqm_on_communication_passes() {
    // Figure 1 left panels: to reach the same (moderate) relative gap,
    // FS needs far fewer size-d passes than SQM. The regime that makes
    // this vivid is the paper's: weak regularization (ill-conditioned ⇒
    // many CG iterations per TRON step ⇒ many passes) and statistically
    // similar shards (random example partition). SQM still wins *deep*
    // accuracy — the paper says so too ("SQM and Hybrid also have the
    // advantage of better convergence when coming close to the
    // optimum").
    let lam = 0.01;
    let d = SynthConfig {
        n_examples: 4_000,
        n_features: 300,
        nnz_per_example: 10,
        skew: 0.5,
        ..SynthConfig::default()
    }
    .generate(2);
    // reference optimum
    let mut c0 = Cluster::partition(d.clone(), 1, CostModel::free());
    let mut rcfg = SqmConfig { lam, ..Default::default() };
    rcfg.tron.eps = 1e-13;
    rcfg.tron.max_iter = 500;
    let fstar = SqmDriver::new(rcfg)
        .run(&mut c0, None, &StopRule::iters(500))
        .f;
    let target = fstar * (1.0 + 1e-4);
    let passes_to_target = |run: &psgd::algo::RunResult| -> f64 {
        run.trace
            .points
            .iter()
            .find(|p| p.f <= target)
            .map(|p| p.comm_passes)
            .unwrap_or(f64::INFINITY)
    };
    let part = psgd::data::partition::Partition::shuffled(d.n_examples(), 8, 5);

    let mut c_fs = Cluster::partition_with(d.clone(), &part, CostModel::free());
    let fs = FsDriver::new(FsConfig { lam, epochs: 8, ..Default::default() })
        .run(&mut c_fs, None, &StopRule::iters(60));

    let mut c_sqm = Cluster::partition_with(d.clone(), &part, CostModel::free());
    let sqm = SqmDriver::new(SqmConfig { lam, ..Default::default() })
        .run(&mut c_sqm, None, &StopRule::iters(60));

    let fs_passes = passes_to_target(&fs);
    let sqm_passes = passes_to_target(&sqm);
    assert!(
        fs_passes.is_finite() && sqm_passes.is_finite(),
        "fs {fs_passes} sqm {sqm_passes}"
    );
    assert!(
        fs_passes < 0.7 * sqm_passes,
        "FS should win clearly on passes: fs={fs_passes} sqm={sqm_passes}"
    );
}

#[test]
fn hybrid_between_sqm_and_fs_early() {
    // Hybrid's mixing init buys it a better start than cold SQM.
    let d = data(3);
    let mut c_sqm = cluster(&d, 8);
    let mut c_hyb = cluster(&d, 8);
    let sqm = SqmDriver::new(SqmConfig { lam: LAM, ..Default::default() })
        .run(&mut c_sqm, None, &StopRule::iters(3));
    let mut hcfg = HybridConfig::default();
    hcfg.sqm.lam = LAM;
    let hyb = HybridDriver::with_objective(hcfg)
        .run(&mut c_hyb, None, &StopRule::iters(3));
    assert!(
        hyb.trace.points[0].f <= sqm.trace.points[0].f,
        "hybrid {} vs sqm {}",
        hyb.trace.points[0].f,
        sqm.trace.points[0].f
    );
}

#[test]
fn node_scaling_does_not_shrink_fs_iterations() {
    // paper: "When the number of nodes is increased, SQM and Hybrid
    // come closer to our method" — because f̂_p approximates f worse,
    // FS needs at least as many outer iterations at higher P.
    let d = data(4);
    let fstar = f_star(&d, LossKind::Logistic);
    let target = fstar * (1.0 + 1e-5);
    let iters_at = |nodes: usize| -> usize {
        let mut c = cluster(&d, nodes);
        let run = FsDriver::new(FsConfig {
            lam: LAM,
            epochs: 2,
            ..Default::default()
        })
        .run(&mut c, None, &StopRule::iters(150).with_target(target));
        run.trace.points.len()
    };
    let small = iters_at(2);
    let large = iters_at(25);
    assert!(
        large >= small,
        "FS outer iterations should not shrink with more nodes: P=2 → {small}, P=25 → {large}"
    );
}

#[test]
fn safeguard_rarely_triggers_with_svrg() {
    // the Theorem-2 story: with a strongly convergent inner solver the
    // safeguard is essentially never needed, even at small s
    let d = data(5);
    let mut c = cluster(&d, 6);
    let run = FsDriver::new(FsConfig {
        lam: LAM,
        epochs: 1,
        ..Default::default()
    })
    .run(&mut c, None, &StopRule::iters(25));
    let total_hits: usize =
        run.trace.points.iter().map(|p| p.safeguard_hits).sum();
    let total_dirs = 6 * run.trace.points.len();
    assert!(
        (total_hits as f64) < 0.05 * total_dirs as f64,
        "safeguard hit {total_hits}/{total_dirs} directions"
    );
}

#[test]
fn param_mix_converges_slower_than_fs_to_tight_gaps() {
    let d = data(6);
    let fstar = f_star(&d, LossKind::Logistic);
    let target = fstar * (1.0 + 1e-6);
    let mut c_fs = cluster(&d, 6);
    let fs = FsDriver::new(FsConfig { lam: LAM, epochs: 2, ..Default::default() })
        .run(&mut c_fs, None, &StopRule::iters(60).with_target(target));
    let mut c_pm = cluster(&d, 6);
    let pm = ParamMixDriver::new(ParamMixConfig {
        lam: LAM,
        epochs: 2,
        ..Default::default()
    })
    .run(&mut c_pm, None, &StopRule::iters(60).with_target(target));
    let fs_gap = (fs.f - fstar) / fstar;
    let pm_gap = (pm.f - fstar) / fstar;
    assert!(
        fs_gap < pm_gap,
        "FS gap {fs_gap} should beat parameter mixing {pm_gap}"
    );
}
