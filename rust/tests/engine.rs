//! Event-engine regression suite.
//!
//! The contract the engine must keep:
//!
//! 1. **Equivalence** — without pipelining the engine runs the
//!    barrier schedule, so the event-driven makespan reproduces the
//!    legacy flat accumulator (`comm_seconds + compute_seconds`) to ε
//!    on a full FS run, for every inner solver and for heterogeneous
//!    profiles too. The engine is a strict refinement, not a
//!    different model.
//! 2. **Bit-identical arithmetic** — `--pipeline` is a schedule: the
//!    objective trace and the final iterate of a pipelined run match
//!    the barrier run exactly.
//! 3. **Straggler hiding** — with one node 3× slower, the pipelined
//!    makespan is strictly lower than the barrier schedule's (the
//!    control plane hides under the straggler's self-paced compute).

use psgd::algo::fs::{FsConfig, FsDriver, InnerSolver};
use psgd::algo::{Driver, StopRule};
use psgd::cluster::{Cluster, CostModel, NodeProfile};
use psgd::data::synth::SynthConfig;
use psgd::util::json::{parse, Value};

fn make_cluster(nodes: usize, seed: u64, cost: CostModel) -> Cluster {
    let data = SynthConfig {
        n_examples: 400,
        n_features: 60,
        nnz_per_example: 8,
        skew: 1.0,
        ..SynthConfig::default()
    }
    .generate(seed);
    let mut c = Cluster::partition(data, nodes, cost);
    c.threads = 1; // contention-free measured compute
    c
}

fn fs_config(inner: InnerSolver, pipeline: bool) -> FsConfig {
    FsConfig {
        lam: 0.5,
        epochs: 2,
        inner,
        lr: if inner == InnerSolver::Sgd { Some(0.01) } else { None },
        pipeline,
        ..Default::default()
    }
}

#[test]
fn homogeneous_engine_reproduces_legacy_seconds_for_all_solvers() {
    for inner in [
        InnerSolver::Svrg,
        InnerSolver::Sag,
        InnerSolver::Sgd,
        InnerSolver::Lbfgs,
        InnerSolver::Tron,
    ] {
        let mut cluster = make_cluster(4, 11, CostModel::default());
        assert!(cluster.engine.profile.is_homogeneous());
        let run = FsDriver::new(fs_config(inner, false)).run(
            &mut cluster,
            None,
            &StopRule::iters(6),
        );
        let flat = run.ledger.comm_seconds + run.ledger.compute_seconds;
        let makespan = run.ledger.seconds();
        assert!(run.ledger.makespan.is_some(), "{inner:?}: engine idle");
        assert!(flat > 0.0, "{inner:?}: nothing charged");
        assert!(
            (makespan - flat).abs() <= 1e-9 * (1.0 + flat),
            "{inner:?}: makespan {makespan} vs flat {flat}"
        );
    }
}

#[test]
fn heterogeneous_barrier_schedule_still_matches_flat_sum() {
    // the per-node profile scales the barrier charge uniformly: a
    // non-pipelined heterogeneous run is still the flat accumulator
    // (odd node count exercises the odd-tail tree pairing too)
    let mut cluster = make_cluster(6, 13, CostModel::default());
    cluster.set_profile(NodeProfile::seeded(6, 9, 2.0));
    let run = FsDriver::new(fs_config(InnerSolver::Svrg, false)).run(
        &mut cluster,
        None,
        &StopRule::iters(5),
    );
    let flat = run.ledger.comm_seconds + run.ledger.compute_seconds;
    let makespan = run.ledger.seconds();
    assert!(
        (makespan - flat).abs() <= 1e-9 * (1.0 + flat),
        "barrier schedule diverged: makespan {makespan} vs flat {flat}"
    );
}

/// A cost model where the control plane is expensive enough to matter
/// and modeled compute dominates measurement noise.
fn pipeline_cost() -> CostModel {
    CostModel {
        latency_s: 0.05,
        compute_scale: 20_000.0,
        ..CostModel::default()
    }
}

#[test]
fn pipelined_schedule_is_bit_identical_and_faster_under_straggler() {
    let straggler = NodeProfile::with_straggler(4, 0, 3.0);

    let mut barrier = make_cluster(4, 17, pipeline_cost());
    barrier.set_profile(straggler.clone());
    let run_b = FsDriver::new(fs_config(InnerSolver::Svrg, false)).run(
        &mut barrier,
        None,
        &StopRule::iters(8),
    );

    let mut piped = make_cluster(4, 17, pipeline_cost());
    piped.set_profile(straggler);
    let run_p = FsDriver::new(fs_config(InnerSolver::Svrg, true)).run(
        &mut piped,
        None,
        &StopRule::iters(8),
    );

    // pipelining is a schedule, not an algorithm change: the iterates
    // and the objective trace are bit-identical
    assert_eq!(run_b.w, run_p.w, "pipelined iterate diverged");
    assert_eq!(
        run_b.trace.points.len(),
        run_p.trace.points.len(),
        "outer iteration counts diverged"
    );
    for (b, p) in run_b.trace.points.iter().zip(&run_p.trace.points) {
        assert_eq!(b.f, p.f, "objective diverged at iter {}", b.iter);
    }
    // the flat component accounting is identical too (same ops ran)
    assert_eq!(run_b.ledger.comm_passes, run_p.ledger.comm_passes);
    assert_eq!(run_b.ledger.comm_bytes, run_p.ledger.comm_bytes);
    assert_eq!(run_b.ledger.scalar_rounds, run_p.ledger.scalar_rounds);

    // ...but the pipelined makespan is strictly lower: the direction
    // allreduce + line search hide under the straggler's next sweep.
    // The margin is absolute virtual seconds (≈ the control-plane time
    // of a couple of rounds), so the assertion is robust to how fast
    // the host measures compute.
    let mb = run_b.ledger.seconds();
    let mp = run_p.ledger.seconds();
    assert!(
        mp < mb - 0.2,
        "pipelined {mp} not meaningfully below barrier {mb}"
    );
}

#[test]
fn timeline_json_schema_matches_documented_shape() {
    // satellite: the --trace-timeline export can't drift from the
    // shape lib.rs documents — parse it back through the in-tree JSON
    // parser and assert every documented key, including the async
    // `staleness` field on events
    let mut cluster = make_cluster(4, 29, CostModel::default());
    let _ = FsDriver::new(fs_config(InnerSolver::Svrg, true)).run(
        &mut cluster,
        None,
        &StopRule::iters(2),
    );
    let json = cluster.engine.timeline_json().to_json(1);
    let v = parse(&json).expect("timeline JSON parses");
    for key in [
        "makespan",
        "nodes",
        "pipeline",
        "profile",
        "dropped_events",
        "events",
    ] {
        assert!(v.get(key).is_some(), "missing top-level key {key}");
    }
    assert_eq!(v.get("dropped_events").unwrap().as_usize(), Some(0));
    assert_eq!(v.get("nodes").unwrap().as_usize(), Some(4));
    assert_eq!(v.get("pipeline").unwrap(), &Value::Bool(true));
    assert!(v.get("makespan").unwrap().as_f64().unwrap() > 0.0);
    let profile = match v.get("profile").unwrap() {
        Value::Arr(p) => p,
        other => panic!("profile is not an array: {other:?}"),
    };
    assert_eq!(profile.len(), 4);
    let events = match v.get("events").unwrap() {
        Value::Arr(e) => e,
        other => panic!("events is not an array: {other:?}"),
    };
    assert!(!events.is_empty());
    for (i, ev) in events.iter().enumerate() {
        for key in ["label", "node", "level", "start", "end", "staleness"] {
            assert!(ev.get(key).is_some(), "event {i} missing {key}");
        }
        assert!(ev.get("label").unwrap().as_str().is_some());
        let start = ev.get("start").unwrap().as_f64().unwrap();
        let end = ev.get("end").unwrap().as_f64().unwrap();
        assert!(end >= start, "event {i} runs backwards");
    }
}

#[test]
fn timeline_records_phases_and_exports_json() {
    let mut cluster = make_cluster(3, 23, CostModel::default());
    let _ = FsDriver::new(fs_config(InnerSolver::Svrg, false)).run(
        &mut cluster,
        None,
        &StopRule::iters(3),
    );
    let events = cluster.engine.events();
    assert!(!events.is_empty());
    assert!(events.iter().any(|e| e.label == "local_solve"));
    assert!(events.iter().any(|e| e.label == "grad_sweep"));
    assert!(events.iter().any(|e| e.label == "scalar_round"));
    assert!(events.iter().all(|e| e.end >= e.start));
    let json = cluster.engine.timeline_json().to_json(0);
    assert!(json.contains("\"makespan\""));
    assert!(json.contains("\"local_solve\""));
    assert_eq!(cluster.engine.dropped_events(), 0);
}
