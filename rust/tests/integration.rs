//! End-to-end integration over the public API: every driver on a
//! shared mid-size problem, trace/CSV/JSON plumbing, config loading,
//! libsvm round trips, failure injection (degenerate shards, extreme λ,
//! empty test sets).

use psgd::algo::autoswitch::{AutoSwitchConfig, AutoSwitchDriver};
use psgd::algo::fs::{Combine, FsConfig, FsDriver};
use psgd::algo::hybrid::{HybridConfig, HybridDriver};
use psgd::algo::param_mix::{ParamMixConfig, ParamMixDriver};
use psgd::algo::sqm::{CoreOpt, SqmConfig, SqmDriver};
use psgd::algo::{Driver, StopRule};
use psgd::cluster::{Cluster, CostModel};
use psgd::data::dataset::Dataset;
use psgd::data::libsvm;
use psgd::data::partition::Partition;
use psgd::data::synth::SynthConfig;
use psgd::loss::LossKind;
use psgd::util::config::Config;
use psgd::util::csv;

fn problem() -> (Dataset, Dataset) {
    SynthConfig {
        n_examples: 600,
        n_features: 80,
        nnz_per_example: 8,
        skew: 1.0,
        ..SynthConfig::default()
    }
    .generate(77)
    .split(0.85, 3)
}

fn drivers() -> Vec<Box<dyn Driver>> {
    let lam = 0.5;
    let mut hybrid = HybridConfig::default();
    hybrid.sqm.lam = lam;
    let mut autosw = AutoSwitchConfig::default();
    autosw.fs.lam = lam;
    autosw.switch_gnorm = 1e-2;
    vec![
        Box::new(FsDriver::new(FsConfig { lam, ..Default::default() })),
        Box::new(FsDriver::new(FsConfig {
            lam,
            combine: Combine::SizeWeighted,
            ..Default::default()
        })),
        Box::new(SqmDriver::new(SqmConfig { lam, ..Default::default() })),
        Box::new(SqmDriver::new(SqmConfig {
            lam,
            core: CoreOpt::Lbfgs,
            ..Default::default()
        })),
        Box::new(HybridDriver::with_objective(hybrid)),
        Box::new(ParamMixDriver::new(ParamMixConfig {
            lam,
            ..Default::default()
        })),
        Box::new(AutoSwitchDriver::new(autosw)),
    ]
}

#[test]
fn every_driver_runs_and_descends() {
    let (train, test) = problem();
    for driver in drivers() {
        let mut cluster =
            Cluster::partition(train.clone(), 5, CostModel::default());
        let run = driver.run(&mut cluster, Some(&test), &StopRule::iters(8));
        let pts = &run.trace.points;
        assert!(!pts.is_empty(), "{} produced no trace", driver.name());
        assert!(
            run.f <= pts[0].f,
            "{} did not descend: {} -> {}",
            driver.name(),
            pts[0].f,
            run.f
        );
        // ledger monotone along the trace
        for k in 1..pts.len() {
            assert!(pts[k].comm_passes >= pts[k - 1].comm_passes);
            assert!(pts[k].seconds >= pts[k - 1].seconds - 1e-12);
        }
        // AUPRC recorded (test set given)
        assert!(pts.iter().any(|p| !p.auprc.is_nan()));
        // simulated time includes modeled comm (non-free cost model)
        assert!(run.ledger.comm_seconds > 0.0);
    }
}

#[test]
fn trace_tables_roundtrip_through_csv_and_json() {
    let (train, test) = problem();
    let mut cluster = Cluster::partition(train, 4, CostModel::default());
    let run = FsDriver::new(FsConfig { lam: 0.5, ..Default::default() })
        .run(&mut cluster, Some(&test), &StopRule::iters(5));
    let table = run.trace.to_table(run.f);
    let parsed = csv::parse(&table.to_csv()).expect("csv parse");
    assert_eq!(parsed.rows.len(), run.trace.points.len());
    assert_eq!(parsed.columns[1], "comm_passes");
    let json = run.trace.to_json(run.f).to_json(2);
    let v = psgd::util::json::parse(&json).expect("json parse");
    assert!(v.get("points").is_some());
}

#[test]
fn libsvm_roundtrip_preserves_training_behaviour() {
    let (train, _) = problem();
    let mut buf = Vec::new();
    libsvm::write(&train, &mut buf).unwrap();
    let reloaded =
        libsvm::read(buf.as_slice(), train.n_features()).expect("reload");
    assert_eq!(train.n_examples(), reloaded.n_examples());
    assert_eq!(train.nnz(), reloaded.nnz());
    // identical FS run on both
    let run = |d: Dataset| {
        let mut c = Cluster::partition(d, 3, CostModel::free());
        FsDriver::new(FsConfig { lam: 0.5, seed: 1, ..Default::default() })
            .run(&mut c, None, &StopRule::iters(4))
            .f
    };
    let a = run(train);
    let b = run(reloaded);
    assert!((a - b).abs() < 1e-6 * a.abs(), "{a} vs {b}");
}

#[test]
fn config_file_drives_settings() {
    let cfg = Config::parse(
        "[train]\nlambda = 0.25\nepochs = 3\nnodes = 6\nloss = \"squared_hinge\"\n",
    )
    .unwrap();
    assert_eq!(cfg.f64("train", "lambda", 0.0), 0.25);
    assert_eq!(cfg.usize("train", "epochs", 0), 3);
    assert_eq!(
        LossKind::parse(cfg.get("train", "loss").unwrap()),
        Some(LossKind::SquaredHinge)
    );
}

#[test]
fn shuffled_vs_contiguous_partition_both_converge() {
    let (train, _) = problem();
    for shuffled in [false, true] {
        let part = if shuffled {
            Partition::shuffled(train.n_examples(), 5, 9)
        } else {
            Partition::contiguous(train.n_examples(), 5)
        };
        let mut cluster =
            Cluster::partition_with(train.clone(), &part, CostModel::free());
        let run = FsDriver::new(FsConfig { lam: 0.5, ..Default::default() })
            .run(&mut cluster, None, &StopRule::iters(10));
        let pts = &run.trace.points;
        assert!(pts.last().unwrap().f < pts[0].f * 0.9);
    }
}

// ---------- failure injection ----------

#[test]
fn survives_degenerate_single_class_shards() {
    // all-positive labels on some shards (contiguous split of sorted
    // labels) must not break anything
    let mut data = SynthConfig {
        n_examples: 200,
        n_features: 40,
        nnz_per_example: 5,
        ..SynthConfig::default()
    }
    .generate(5);
    // sort labels so shards are single-class
    let mut idx: Vec<usize> = (0..data.n_examples()).collect();
    idx.sort_by(|&a, &b| data.y[a].partial_cmp(&data.y[b]).unwrap());
    data = data.take(&idx);
    let mut cluster = Cluster::partition(data, 4, CostModel::free());
    let run = FsDriver::new(FsConfig { lam: 0.5, ..Default::default() })
        .run(&mut cluster, None, &StopRule::iters(6));
    assert!(run.f.is_finite());
    assert!(run.trace.points.last().unwrap().f <= run.trace.points[0].f);
}

#[test]
fn survives_extreme_regularization() {
    let (train, _) = problem();
    for lam in [1e-9, 1e4] {
        let mut cluster = Cluster::partition(train.clone(), 3, CostModel::free());
        let run = FsDriver::new(FsConfig { lam, ..Default::default() })
            .run(&mut cluster, None, &StopRule::iters(5));
        assert!(run.f.is_finite(), "λ={lam}");
        // at huge λ the solution collapses to ~0
        if lam > 1.0 {
            let wnorm = psgd::linalg::dense::norm(&run.w);
            assert!(wnorm < 1.0, "λ={lam}, ‖w‖={wnorm}");
        }
    }
}

#[test]
fn empty_test_set_yields_nan_auprc_not_panic() {
    let (train, _) = problem();
    let mut cluster = Cluster::partition(train, 3, CostModel::free());
    let run = FsDriver::new(FsConfig { lam: 0.5, ..Default::default() })
        .run(&mut cluster, None, &StopRule::iters(3));
    assert!(run.trace.points.iter().all(|p| p.auprc.is_nan()));
}

#[test]
fn stop_rule_budget_respected() {
    let (train, _) = problem();
    let mut cluster = Cluster::partition(train, 4, CostModel::default());
    let run = FsDriver::new(FsConfig { lam: 0.5, ..Default::default() })
        .run(&mut cluster, None, &StopRule::budget(12.0, f64::INFINITY));
    // 3 passes at iter 0, +4 per iteration; budget 12 → stops once
    // passes ≥ 12, i.e. ≤ 4 recorded points
    assert!(
        run.ledger.comm_passes <= 12.0 + 4.0,
        "passes {}",
        run.ledger.comm_passes
    );
}

#[test]
fn single_example_per_node_edge_case() {
    let data = SynthConfig {
        n_examples: 6,
        n_features: 10,
        nnz_per_example: 3,
        ..SynthConfig::default()
    }
    .generate(8);
    let mut cluster = Cluster::partition(data, 6, CostModel::free());
    let run = FsDriver::new(FsConfig {
        lam: 0.5,
        batch: 1,
        ..Default::default()
    })
    .run(&mut cluster, None, &StopRule::iters(4));
    assert!(run.f.is_finite());
}
