//! Compact-solve contract tests: every inner solver (SVRG, SAG, plain
//! SGD, L-BFGS, TRON) run on the support-compact [`CompactApprox`] must
//! reproduce the full-space solve on [`LocalApprox`] to rounding error —
//! across skewed shards, an all-dense shard (support = every column)
//! and a 1-nnz shard (support = one column), with tilts that move every
//! off-support coordinate. This is the invariant that lets the FS
//! driver run all local solves in O(|support|) buffers and ship
//! directions as support-sized corrections.

use psgd::linalg::{dense, Csr, SupportMap};
use psgd::loss::LossKind;
use psgd::objective::compact::{CompactApprox, GlobalDots, HybridDir};
use psgd::objective::{shard_loss_grad, LocalApprox, Objective};
use psgd::opt::lbfgs::{self, LbfgsParams};
use psgd::opt::sag::{sag_epochs, SagParams};
use psgd::opt::sgd::{sgd_epochs, sgd_epochs_shrink, SgdParams};
use psgd::opt::svrg::{svrg_epochs, SvrgParams};
use psgd::opt::tron::{self, TronParams};
use psgd::util::rng::Rng;

struct Problem {
    x: Csr,
    y: Vec<f64>,
    w_r: Vec<f64>,
    g_r: Vec<f64>,
    lam: f64,
}

fn skewed(seed: u64, dim: usize, n: usize, max_nnz: usize) -> Problem {
    let mut rng = Rng::new(seed);
    let rows: Vec<Vec<(u32, f32)>> = (0..n)
        .map(|_| {
            (0..1 + rng.below(max_nnz))
                .map(|_| (rng.below(dim) as u32, rng.range(-2.0, 2.0) as f32))
                .collect()
        })
        .collect();
    let x = Csr::from_rows(dim, &rows);
    let y: Vec<f64> = (0..n).map(|_| rng.sign()).collect();
    let w_r: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.3).collect();
    finish(x, y, w_r, 0.7, &mut rng)
}

fn all_dense(seed: u64, dim: usize, n: usize) -> Problem {
    let mut rng = Rng::new(seed);
    let rows: Vec<Vec<(u32, f32)>> = (0..n)
        .map(|_| {
            (0..dim as u32)
                .map(|c| (c, rng.range(-1.0, 1.0) as f32))
                .collect()
        })
        .collect();
    let x = Csr::from_rows(dim, &rows);
    let y: Vec<f64> = (0..n).map(|_| rng.sign()).collect();
    let w_r: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.2).collect();
    finish(x, y, w_r, 0.5, &mut rng)
}

fn one_nnz(seed: u64, dim: usize) -> Problem {
    let mut rng = Rng::new(seed);
    let x = Csr::from_rows(dim, &[vec![(7u32, 1.5f32)]]);
    let y = vec![1.0];
    let w_r: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.3).collect();
    finish(x, y, w_r, 1.0, &mut rng)
}

/// Attach a plausible global gradient: ∇L_p(wʳ) + λwʳ + a perturbation
/// so the tilt genuinely moves every (off-support included) coordinate.
fn finish(x: Csr, y: Vec<f64>, w_r: Vec<f64>, lam: f64, rng: &mut Rng) -> Problem {
    let dim = x.n_cols;
    let mut grad_lp = vec![0.0; dim];
    shard_loss_grad(&x, &y, &w_r, LossKind::Logistic, &mut grad_lp, None);
    let mut g_r = grad_lp;
    for (j, gj) in g_r.iter_mut().enumerate() {
        *gj += lam * w_r[j] + rng.normal() * 0.5;
    }
    Problem { x, y, w_r, g_r, lam }
}

struct CompactSetup {
    map: SupportMap,
    xl: Csr,
    wr_c: Vec<f64>,
    g_c: Vec<f64>,
    glp_c: Vec<f64>,
    dots: GlobalDots,
    grad_lp: Vec<f64>,
}

fn compact_setup(p: &Problem) -> CompactSetup {
    let dim = p.x.n_cols;
    let (map, xl) = SupportMap::compact(&p.x);
    let mut grad_lp = vec![0.0; dim];
    shard_loss_grad(&p.x, &p.y, &p.w_r, LossKind::Logistic, &mut grad_lp, None);
    let (mut wr_c, mut g_c, mut glp_c) = (Vec::new(), Vec::new(), Vec::new());
    map.gather(&p.w_r, &mut wr_c);
    map.gather(&p.g_r, &mut g_c);
    map.gather(&grad_lp, &mut glp_c);
    let dots = GlobalDots::compute(&p.w_r, &p.g_r);
    CompactSetup { map, xl, wr_c, g_c, glp_c, dots, grad_lp }
}

/// Reconstruct the full-space solve result from a compact one.
fn reconstruct(
    p: &Problem,
    cs: &CompactSetup,
    ca: &CompactApprox,
    w_p: &[f64],
) -> Vec<f64> {
    let (a_w, a_g) = ca.off_support_coeffs(w_p);
    let hd = HybridDir::from_compact(
        &cs.map,
        p.x.n_cols,
        a_w,
        a_g,
        w_p,
        &cs.wr_c,
        &cs.g_c,
    );
    let mut w_full = p.w_r.clone();
    dense::axpy(1.0, &hd.to_dense(&p.w_r, &p.g_r), &mut w_full);
    w_full
}

fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    let scale = 1.0
        + a.iter().fold(0.0f64, |m, v| m.max(v.abs()))
        + b.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    let diff = dense::max_abs_diff(a, b);
    assert!(diff < tol * scale, "{what}: max diff {diff} (scale {scale})");
}

/// Run every solver both ways on one problem instance.
fn check_all_solvers(p: &Problem, tag: &str) {
    let loss = LossKind::Logistic;
    let cs = compact_setup(p);
    let full = LocalApprox::new(
        &p.x, &p.y, loss, p.lam, &p.w_r, &p.g_r, &cs.grad_lp,
    );
    let ca = CompactApprox::build(
        &cs.xl, &p.y, loss, p.lam, &cs.dots, &cs.wr_c, &cs.g_c, &cs.glp_c,
    );

    // sanity: the two views value-agree at matched points
    let v_full = full.value(&p.w_r);
    let v_compact = ca.value(&ca.w_r);
    assert!(
        (v_full - v_compact).abs() < 1e-8 * (1.0 + v_full.abs()),
        "{tag}: f̂(wʳ) {v_full} vs compact {v_compact}"
    );

    // --- SVRG ---
    let sp = SvrgParams { epochs: 3, batch: 4, lr: None, seed: 11 };
    let w_f = svrg_epochs(&full, &p.w_r, &sp).0;
    let w_c = svrg_epochs(&ca, &ca.w_r, &sp).0;
    assert_close(&w_f, &reconstruct(p, &cs, &ca, &w_c), 1e-9, &format!("{tag}/svrg"));

    // --- SAG ---
    let gp = SagParams { epochs: 2, lr: None, seed: 12 };
    let w_f = sag_epochs(&full, &p.w_r, &gp);
    let w_c = sag_epochs(&ca, &ca.w_r, &gp);
    assert_close(&w_f, &reconstruct(p, &cs, &ca, &w_c), 1e-9, &format!("{tag}/sag"));

    // --- plain SGD (untilted f̃_p) ---
    let dp = SgdParams { epochs: 2, eta0: 0.05, seed: 13 };
    let w_f = sgd_epochs(&p.x, &p.y, loss, p.lam, &p.w_r, &dp);
    let (w_c, shrink) =
        sgd_epochs_shrink(&cs.xl, &p.y, loss, p.lam, &cs.wr_c, &dp);
    let hd = HybridDir::from_compact(
        &cs.map,
        p.x.n_cols,
        shrink - 1.0,
        0.0,
        &w_c,
        &cs.wr_c,
        &cs.g_c,
    );
    let mut w_rec = p.w_r.clone();
    dense::axpy(1.0, &hd.to_dense(&p.w_r, &p.g_r), &mut w_rec);
    assert_close(&w_f, &w_rec, 1e-9, &format!("{tag}/sgd"));

    // --- L-BFGS ---
    let lp = LbfgsParams { max_iter: 5, eps: 1e-10, ..Default::default() };
    let w_f = lbfgs::minimize(&full, &p.w_r, &lp).w;
    let w_c = lbfgs::minimize(&ca, &ca.w_r, &lp).w;
    assert_close(
        &w_f,
        &reconstruct(p, &cs, &ca, &w_c),
        1e-6,
        &format!("{tag}/lbfgs"),
    );

    // --- TRON ---
    let tp = TronParams { max_iter: 3, eps: 1e-10, ..Default::default() };
    let w_f = tron::minimize(&full, &p.w_r, &tp).w;
    let w_c = tron::minimize(&ca, &ca.w_r, &tp).w;
    assert_close(
        &w_f,
        &reconstruct(p, &cs, &ca, &w_c),
        1e-6,
        &format!("{tag}/tron"),
    );
}

#[test]
fn compact_solves_match_full_space_on_skewed_shards() {
    for seed in [1u64, 2, 3, 4, 5] {
        let p = skewed(seed, 60, 40, 6);
        check_all_solvers(&p, &format!("skewed-{seed}"));
    }
}

#[test]
fn compact_solves_match_full_space_on_all_dense_shard() {
    // support = every column: the tail is empty and compact == full
    let p = all_dense(7, 12, 15);
    let cs = compact_setup(&p);
    assert_eq!(cs.map.len(), 12);
    check_all_solvers(&p, "all-dense");
}

#[test]
fn compact_solves_match_full_space_on_one_nnz_shard() {
    // support = a single column; everything else lives in the tail
    let p = one_nnz(9, 40);
    let cs = compact_setup(&p);
    assert_eq!(cs.map.len(), 1);
    check_all_solvers(&p, "one-nnz");
}

#[test]
fn compact_dim_is_support_plus_tail() {
    let p = skewed(21, 300, 10, 4);
    let cs = compact_setup(&p);
    let ca = CompactApprox::build(
        &cs.xl,
        &p.y,
        LossKind::Logistic,
        p.lam,
        &cs.dots,
        &cs.wr_c,
        &cs.g_c,
        &cs.glp_c,
    );
    // the whole point: the solve space is |support| + ≤2, not d
    assert!(cs.map.len() < 300 / 2, "support {} of 300", cs.map.len());
    assert_eq!(ca.dim(), cs.map.len() + ca.tail.k);
    assert!(ca.tail.k <= 2);
}
