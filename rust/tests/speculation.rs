//! Speculative solver lanes + self-tuning (τ, q) acceptance suite.
//!
//! The contract the speculation layer and the adaptive controller
//! must keep:
//!
//! 1. **`Asynchrony::Sync` IS Algorithm 1** — the typed sync policy
//!    reproduces the synchronous `FsDriver` run bit-identically, even
//!    with `speculate: true` (τ = 0 leaves nothing to speculate on).
//! 2. **Speculation is timing-only** — under a full quorum the
//!    speculative run commits the same iterates, objective trace, and
//!    pass accounting as the plain run, bit for bit; only the virtual
//!    schedule (and the spec counters) may differ. `speculate: false`
//!    leaves the ledger and timeline clean of speculation entirely.
//! 3. **The controller is a pure ledger function** — two identical
//!    seeded chaos runs replay the same `tune_trace` decision sequence
//!    bit-identically, and every decision respects the configured
//!    `TuneBounds` box and the live membership.
//! 4. **Degenerate adaptive = fixed policy** — an `Adaptive` policy
//!    whose bounds pin (τ, q) at its init commits the same run as the
//!    equivalent `Bounded` policy.

use psgd::algo::adapt::{Asynchrony, Quorum, TuneBounds};
use psgd::algo::async_fs::{AsyncFsConfig, AsyncFsDriver};
use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::{Driver, RunResult, StopRule};
use psgd::cluster::{Cluster, CostModel, FaultPlan, NodeProfile};
use psgd::data::dataset::Dataset;
use psgd::data::synth::SynthConfig;

/// Same sparse-regime data the async suite pins.
fn make_data(seed: u64) -> Dataset {
    SynthConfig {
        n_examples: 400,
        n_features: 2_000,
        nnz_per_example: 5,
        skew: 1.0,
        ..SynthConfig::default()
    }
    .generate(seed)
}

/// Modeled-time cluster: latency advances the virtual clock every
/// round (so speculation windows open), while `compute_scale: 0`
/// removes measured wall time from the schedule — every run is
/// bit-deterministic, which is what the replay gates need.
fn modeled_cluster(nodes: usize, seed: u64) -> Cluster {
    let cost = CostModel { compute_scale: 0.0, ..CostModel::default() };
    let mut c = Cluster::partition(make_data(seed), nodes, cost);
    c.threads = 1;
    c
}

fn fs_config() -> FsConfig {
    FsConfig { lam: 0.5, epochs: 2, ..Default::default() }
}

fn run_async(
    cluster: &mut Cluster,
    policy: Asynchrony,
    speculate: bool,
    iters: usize,
) -> RunResult {
    AsyncFsDriver::new(AsyncFsConfig {
        fs: fs_config(),
        policy,
        speculate,
    })
    .run(cluster, None, &StopRule::iters(iters))
}

fn assert_same_maths(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.w, b.w, "{what}: iterates diverged");
    assert_eq!(
        a.trace.points.len(),
        b.trace.points.len(),
        "{what}: outer iteration counts diverged"
    );
    for (p, q) in a.trace.points.iter().zip(&b.trace.points) {
        assert_eq!(p.f, q.f, "{what}: objective diverged at iter {}", p.iter);
        assert_eq!(
            p.comm_passes, q.comm_passes,
            "{what}: pass accounting diverged at iter {}",
            p.iter
        );
        assert_eq!(
            p.safeguard_hits, q.safeguard_hits,
            "{what}: safeguard counts diverged at iter {}",
            p.iter
        );
    }
}

#[test]
fn sync_policy_is_bit_identical_to_synchronous_fs() {
    let nodes = 4;
    let mut sync = Cluster::partition(
        make_data(2),
        nodes,
        CostModel::default(),
    );
    sync.threads = 1;
    let mut asynch = Cluster::partition(
        make_data(2),
        nodes,
        CostModel::default(),
    );
    asynch.threads = 1;
    // heterogeneity must not matter: Sync resolves to τ=0, q=P, and
    // the deadline is the last fresh solve — the synchronous barrier
    let profile = NodeProfile::with_straggler(nodes, 0, 3.0);
    sync.set_profile(profile.clone());
    asynch.set_profile(profile);

    assert_eq!(Asynchrony::Sync.tag(), "sync");
    let run_s =
        FsDriver::new(fs_config()).run(&mut sync, None, &StopRule::iters(8));
    // speculate: true on purpose — τ=0 expires every round-(r−1) solve
    // before it could seed a window, so the flag must be inert
    let run_a = run_async(&mut asynch, Asynchrony::Sync, true, 8);

    assert_same_maths(&run_s, &run_a, "sync policy");
    assert_eq!(asynch.ledger.fallback_rounds, 0);
    assert_eq!(
        asynch.ledger.spec_hits + asynch.ledger.spec_misses,
        0,
        "τ=0 left a speculation window open"
    );
    assert!(asynch.ledger.tune_trace.is_empty(), "sync policy tuned");
}

#[test]
fn speculation_is_timing_only_under_full_quorum() {
    let nodes = 4;
    let policy = Asynchrony::Bounded { tau: 2, quorum: Quorum::All };
    let mut plain = modeled_cluster(nodes, 3);
    let mut spec = modeled_cluster(nodes, 3);

    let run_p = run_async(&mut plain, policy, false, 12);
    let run_s = run_async(&mut spec, policy, true, 12);

    // the maths is invariant: speculation only re-times the schedule
    assert_same_maths(&run_p, &run_s, "speculate on/off");
    assert_eq!(
        plain.ledger.staleness_hist, spec.ledger.staleness_hist,
        "speculation changed what the master combined"
    );
    // ...but the speculative run really speculated
    let windows = spec.ledger.spec_hits + spec.ledger.spec_misses;
    assert!(windows > 0, "no speculation window ever classified");
    if spec.ledger.spec_hits > 0 {
        assert!(
            spec.engine.events().iter().any(|e| e.label == "spec_solve"),
            "hits recorded but no spec_solve span on the timeline"
        );
    }
    // the off path is clean: no counters, no spans, no rebase charge
    assert_eq!(plain.ledger.spec_hits, 0);
    assert_eq!(plain.ledger.spec_misses, 0);
    assert_eq!(plain.ledger.spec_rebase_seconds, 0.0);
    assert!(!plain.engine.events().iter().any(|e| {
        e.label == "spec_solve" || e.label == "speculation_rebase"
    }));
}

#[test]
fn controller_trace_replays_bit_identically_under_seeded_chaos() {
    let nodes = 5;
    let policy = Asynchrony::Adaptive {
        init: (1, nodes - 1),
        bounds: TuneBounds { tau_max: 4, q_min: 1 },
    };
    let run = || {
        let mut cluster = modeled_cluster(nodes, 3);
        cluster.set_fault_plan(FaultPlan::seeded(nodes, 1));
        let run = run_async(&mut cluster, policy, true, 24);
        (run, cluster.ledger.clone())
    };

    let (run_a, ledger_a) = run();
    let (run_b, ledger_b) = run();

    assert!(
        ledger_a.has_fault_activity(),
        "seeded weather was a no-op; the replay gate lost its teeth"
    );
    assert!(
        !ledger_a.tune_trace.is_empty(),
        "24 rounds never completed a tuning window"
    );
    assert_eq!(run_a.w, run_b.w, "seeded replay diverged in the iterates");
    assert_eq!(
        ledger_a, ledger_b,
        "seeded replay diverged in the ledger (tune_trace included)"
    );
}

#[test]
fn tuning_decisions_respect_the_bounds_box() {
    let nodes = 5;
    let bounds = TuneBounds { tau_max: 3, q_min: 2 };
    let mut cluster = modeled_cluster(nodes, 3);
    cluster.set_fault_plan(FaultPlan::seeded(nodes, 7));
    let _ = run_async(
        &mut cluster,
        Asynchrony::Adaptive { init: (1, nodes - 1), bounds },
        true,
        24,
    );

    assert!(!cluster.ledger.tune_trace.is_empty());
    for &(tau, q) in &cluster.ledger.tune_trace {
        assert!(tau <= bounds.tau_max, "τ={tau} escaped tau_max");
        assert!(q >= 1, "q collapsed to zero");
        assert!(q <= nodes, "q={q} exceeded the cluster size");
    }
}

#[test]
fn degenerate_adaptive_matches_the_fixed_policy() {
    let nodes = 4;
    // bounds pin (τ, q) exactly at init: calm-weather growth is capped
    // at tau_max=τ and clamped back to q=P, so every window re-decides
    // the same point
    let adaptive = Asynchrony::Adaptive {
        init: (2, nodes),
        bounds: TuneBounds { tau_max: 2, q_min: nodes },
    };
    let fixed = Asynchrony::Bounded { tau: 2, quorum: Quorum::All };
    assert_eq!(adaptive.initial(nodes), fixed.initial(nodes));

    let mut a = modeled_cluster(nodes, 5);
    let mut b = modeled_cluster(nodes, 5);
    let run_a = run_async(&mut a, adaptive, true, 16);
    let run_b = run_async(&mut b, fixed, true, 16);

    assert_same_maths(&run_a, &run_b, "degenerate adaptive");
    assert!(
        !a.ledger.tune_trace.is_empty(),
        "16 rounds never completed a tuning window"
    );
    for &d in &a.ledger.tune_trace {
        assert_eq!(d, (2, nodes), "pinned controller moved");
    }
    assert!(b.ledger.tune_trace.is_empty(), "fixed policy tuned");
}
