//! Sparse-pipeline contract tests: the index/value gradient path must
//! agree with the dense path to 1e-12 on arbitrary (skewed) shards —
//! including an all-dense shard and a 1-nnz shard — and must charge the
//! ledger fewer comm-seconds and bytes on high-d/low-nnz data while
//! keeping the paper's logical pass counts intact.

use psgd::algo::common::{global_value_grad, global_value_grad_auto};
use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::{Driver, StopRule};
use psgd::cluster::allreduce::{tree_sum, tree_sum_sparse};
use psgd::cluster::{Cluster, CostModel};
use psgd::data::synth::SynthConfig;
use psgd::linalg::{dense, Csr, SparseVec, SupportMap};
use psgd::loss::{LossKind, ALL_LOSSES};
use psgd::objective::{shard_loss_grad, shard_loss_grad_sparse};
use psgd::util::prop::check_msg;

type GradCase = (usize, Vec<Vec<(u32, f32)>>, Vec<f64>, Vec<f64>);

fn compare_paths(
    dim: usize,
    rows: &[Vec<(u32, f32)>],
    y: &[f64],
    w: &[f64],
) -> Result<(), String> {
    let x = Csr::from_rows(dim, rows);
    let (map, xl) = SupportMap::compact(&x);
    let mut w_c = Vec::new();
    map.gather(w, &mut w_c);
    for loss in ALL_LOSSES {
        let mut g_dense = vec![0.0; dim];
        let mut z_dense = Vec::new();
        let v_dense =
            shard_loss_grad(&x, y, w, loss, &mut g_dense, Some(&mut z_dense));
        let mut z_sparse = Vec::new();
        let (v_sparse, g_sparse) = shard_loss_grad_sparse(
            &xl, y, &w_c, loss, &map, dim, Some(&mut z_sparse),
        );
        if (v_dense - v_sparse).abs() > 1e-12 * (1.0 + v_dense.abs()) {
            return Err(format!(
                "loss value mismatch ({loss:?}): {v_dense} vs {v_sparse}"
            ));
        }
        let diff = dense::max_abs_diff(&g_dense, &g_sparse.to_dense());
        if diff > 1e-12 {
            return Err(format!("gradient mismatch ({loss:?}): {diff}"));
        }
        if z_dense != z_sparse {
            return Err(format!("margin mismatch ({loss:?})"));
        }
    }
    Ok(())
}

#[test]
fn sparse_and_dense_shard_gradients_agree() {
    check_msg(
        "sparse shard gradient == dense shard gradient",
        40,
        |rng| -> GradCase {
            let dim = 8 + rng.below(120);
            let n = 1 + rng.below(25);
            let rows: Vec<Vec<(u32, f32)>> = (0..n)
                .map(|_| {
                    // skewed nnz: some rows near-empty, some near-dense
                    let nnz = 1 + rng.below(dim.min(12));
                    (0..nnz)
                        .map(|_| {
                            (rng.below(dim) as u32, rng.range(-2.0, 2.0) as f32)
                        })
                        .collect()
                })
                .collect();
            let y: Vec<f64> = (0..n).map(|_| rng.sign()).collect();
            let w: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.4).collect();
            (dim, rows, y, w)
        },
        |(dim, rows, y, w)| compare_paths(*dim, rows, y, w),
    );
}

#[test]
fn edge_shards_all_dense_and_single_nnz() {
    // all-dense shard: every row touches every column — the sparse path
    // must degrade gracefully (support == all columns), not break
    let dim = 12;
    let rows: Vec<Vec<(u32, f32)>> = (0..6)
        .map(|i| {
            (0..dim as u32)
                .map(|c| (c, (i + 1) as f32 * 0.1 + c as f32 * 0.03))
                .collect()
        })
        .collect();
    let y: Vec<f64> = (0..6).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let w: Vec<f64> = (0..dim).map(|j| (j as f64 * 0.4).cos() * 0.3).collect();
    compare_paths(dim, &rows, &y, &w).unwrap();
    let x = Csr::from_rows(dim, &rows);
    assert_eq!(SupportMap::build(&x).density(dim), 1.0);

    // 1-nnz shard: a single example touching a single column
    let rows1 = vec![vec![(7u32, 1.5f32)]];
    compare_paths(dim, &rows1, &[1.0], &w).unwrap();
    let x1 = Csr::from_rows(dim, &rows1);
    let (map1, xl1) = SupportMap::compact(&x1);
    assert_eq!(map1.support, vec![7]);
    let mut w1c = Vec::new();
    map1.gather(&w, &mut w1c);
    let (_, g1) = shard_loss_grad_sparse(
        &xl1,
        &[1.0],
        &w1c,
        LossKind::Logistic,
        &map1,
        dim,
        None,
    );
    assert!(g1.nnz() <= 1);
}

#[test]
fn sparse_tree_reduction_agrees_with_dense_on_skewed_parts() {
    check_msg(
        "tree_sum_sparse == tree_sum",
        30,
        |rng| {
            let dim = 4 + rng.below(80);
            let nodes = 1 + rng.below(13);
            let parts: Vec<Vec<f64>> = (0..nodes)
                .map(|_| {
                    // mixed densities: some nodes near-empty, some full
                    let keep = 1 + rng.below(4);
                    (0..dim)
                        .map(|_| {
                            if rng.below(4) < keep {
                                rng.normal()
                            } else {
                                0.0
                            }
                        })
                        .collect()
                })
                .collect();
            parts
        },
        |parts| {
            let want = tree_sum(parts);
            let sparse_parts: Vec<SparseVec> =
                parts.iter().map(|p| SparseVec::from_dense(p)).collect();
            let (got, _levels) = tree_sum_sparse(&sparse_parts);
            let diff = dense::max_abs_diff(&want, &got.into_dense());
            if diff > 1e-12 {
                return Err(format!("reduction mismatch: {diff}"));
            }
            Ok(())
        },
    );
}

#[test]
fn sparse_round_charges_fewer_comm_seconds_and_bytes() {
    // kdd2010-shaped regime at repro scale: d ≫ per-shard support
    let data = SynthConfig {
        n_examples: 2_000,
        n_features: 200_000,
        nnz_per_example: 10,
        ..SynthConfig::default()
    }
    .generate(9);
    let c0 = Cluster::partition(data, 8, CostModel::default());
    let mut c_dense = c0.fork_fresh();
    let mut c_sparse = c0.fork_fresh();
    assert!(
        c_sparse.prefer_sparse(),
        "support density {} should trigger the sparse path",
        c_sparse.support_density()
    );
    let w = vec![0.0; c0.dim];
    let loss = LossKind::Logistic;
    let (f_d, g_d, _, _) = global_value_grad(&mut c_dense, &w, loss, 0.5, true);
    let (f_s, g_s, _, _) =
        global_value_grad_auto(&mut c_sparse, &w, loss, 0.5, true, true);
    assert!((f_d - f_s).abs() < 1e-9 * (1.0 + f_d.abs()));
    assert!(dense::max_abs_diff(&g_d, &g_s) < 1e-12);
    // the paper's logical pass count is wire-format independent ...
    assert_eq!(c_dense.ledger.comm_passes, c_sparse.ledger.comm_passes);
    // ... but the sparse round moves far fewer bytes and seconds
    assert!(
        c_sparse.ledger.comm_bytes < 0.5 * c_dense.ledger.comm_bytes,
        "bytes: sparse {} vs dense {}",
        c_sparse.ledger.comm_bytes,
        c_dense.ledger.comm_bytes
    );
    assert!(
        c_sparse.ledger.comm_seconds < c_dense.ledger.comm_seconds,
        "seconds: sparse {} vs dense {}",
        c_sparse.ledger.comm_seconds,
        c_dense.ledger.comm_seconds
    );
}

#[test]
fn fs_on_the_sparse_path_descends_with_the_paper_pass_profile() {
    let data = SynthConfig {
        n_examples: 240,
        n_features: 4_000,
        nnz_per_example: 5,
        ..SynthConfig::default()
    }
    .generate(13);
    let mut cluster = Cluster::partition(data, 4, CostModel::default());
    assert!(cluster.prefer_sparse());
    let run = FsDriver::new(FsConfig { lam: 0.5, ..Default::default() })
        .run(&mut cluster, None, &StopRule::iters(6));
    let pts = &run.trace.points;
    assert!(pts.len() > 1);
    assert!(run.f.is_finite());
    assert!(pts.last().unwrap().f < pts[0].f, "no descent on sparse path");
    // w⁰ broadcast + gradient allreduce, then 4 passes per iteration —
    // unchanged by the sparse wire format
    assert_eq!(pts[0].comm_passes, 3.0);
    for k in 1..pts.len() {
        assert_eq!(
            pts[k].comm_passes - pts[k - 1].comm_passes,
            4.0,
            "iteration {k} pass profile changed"
        );
    }
    assert!(cluster.ledger.comm_bytes > 0.0);
}
