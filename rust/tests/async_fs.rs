//! Bounded-staleness async FS regression suite.
//!
//! The contract the driver must keep:
//!
//! 1. **Degeneration** — τ = 0 with a full quorum is Algorithm 1: the
//!    async driver reproduces the synchronous FS run *bit-identically*
//!    (iterates, objective trace, pass counts), for any node profile.
//! 2. **Convergence under staleness** — for τ ∈ {1, 2} with a partial
//!    quorum under a 3× straggler profile, the run still reaches the
//!    same relative-gap tolerance the synchronous suite pins, every
//!    combined contribution respects the staleness bound, and the
//!    objective stays monotone (every committed direction is θ-cone
//!    descent or the certified fallback).
//! 3. **The safeguard gate fires** — on an adversarial label-sorted
//!    shard split with a tight θ and a stale-dominated quorum, at
//!    least one round's combined direction fails sufficient descent
//!    and falls back to the synchronous barrier direction.

use psgd::algo::adapt::{Asynchrony, Quorum};
use psgd::algo::async_fs::{AsyncFsConfig, AsyncFsDriver};
use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::safeguard::Safeguard;
use psgd::algo::{Driver, StopRule};
use psgd::cluster::{Cluster, CostModel, NodeProfile};
use psgd::data::dataset::Dataset;
use psgd::data::partition::Partition;
use psgd::data::synth::SynthConfig;
use psgd::loss::LossKind;
use psgd::objective::RegularizedLoss;
use psgd::opt::tron::{self, TronParams};

/// High-dimensional, sparse-regime data (the paper's regime, and the
/// one where the hybrid wire format is exercised end to end).
fn make_data(seed: u64) -> Dataset {
    SynthConfig {
        n_examples: 400,
        n_features: 2_000,
        nnz_per_example: 5,
        skew: 1.0,
        ..SynthConfig::default()
    }
    .generate(seed)
}

fn make_cluster(nodes: usize, seed: u64) -> Cluster {
    let mut c =
        Cluster::partition(make_data(seed), nodes, CostModel::default());
    c.threads = 1; // contention-free measured compute
    c
}

fn fs_config() -> FsConfig {
    FsConfig { lam: 0.5, epochs: 2, ..Default::default() }
}

/// Exact optimum of the stitched problem via TRON (the synchronous
/// suite's oracle).
fn f_star(cluster: &Cluster, loss: LossKind, lam: f64) -> f64 {
    let mut rows = Vec::new();
    let mut ys = Vec::new();
    for s in &cluster.shards {
        for i in 0..s.xl.n_rows() {
            rows.push(s.row_global(i));
            ys.push(s.y[i]);
        }
    }
    let x = psgd::linalg::Csr::from_rows(cluster.dim, &rows);
    let obj = RegularizedLoss { x: &x, y: &ys, loss, lam };
    tron::minimize(&obj, &vec![0.0; cluster.dim], &TronParams {
        eps: 1e-12,
        max_iter: 200,
        ..Default::default()
    })
    .f
}

#[test]
fn tau0_full_quorum_is_bit_identical_to_synchronous_fs() {
    let nodes = 4;
    let mut sync = make_cluster(nodes, 2);
    let mut asynch = make_cluster(nodes, 2);
    // a heterogeneous profile must not matter: with τ=0 and q=P the
    // deadline is the last fresh solve, i.e. the synchronous barrier
    let profile = NodeProfile::with_straggler(nodes, 0, 3.0);
    sync.set_profile(profile.clone());
    asynch.set_profile(profile);
    assert!(sync.prefer_sparse(), "test data must be sparse-regime");

    let run_s = FsDriver::new(fs_config()).run(
        &mut sync,
        None,
        &StopRule::iters(8),
    );
    let run_a = AsyncFsDriver::new(AsyncFsConfig {
        fs: fs_config(),
        policy: Asynchrony::Bounded { tau: 0, quorum: Quorum::All },
        ..Default::default()
    })
    .run(&mut asynch, None, &StopRule::iters(8));

    assert_eq!(run_s.w, run_a.w, "iterates diverged");
    assert_eq!(
        run_s.trace.points.len(),
        run_a.trace.points.len(),
        "outer iteration counts diverged"
    );
    for (s, a) in run_s.trace.points.iter().zip(&run_a.trace.points) {
        assert_eq!(s.f, a.f, "objective diverged at iter {}", s.iter);
        assert_eq!(
            s.comm_passes, a.comm_passes,
            "pass accounting diverged at iter {}",
            s.iter
        );
        assert_eq!(
            s.safeguard_hits, a.safeguard_hits,
            "safeguard counts diverged at iter {}",
            s.iter
        );
    }
    // every combined contribution was fresh, nothing fell back
    assert!(asynch.ledger.async_rounds > 0);
    assert_eq!(
        asynch.ledger.staleness_hist,
        vec![nodes * asynch.ledger.async_rounds],
        "non-fresh contribution under τ=0, q=P"
    );
    assert_eq!(asynch.ledger.fallback_rounds, 0);
}

#[test]
fn stale_quorum_converges_under_straggler() {
    for tau in [1usize, 2] {
        let nodes = 5;
        let mut cluster = make_cluster(nodes, 3);
        cluster.set_profile(NodeProfile::with_straggler(nodes, 0, 3.0));
        let cfg = fs_config();
        let fstar = f_star(&cluster, cfg.loss, cfg.lam);
        let run = AsyncFsDriver::new(AsyncFsConfig {
            fs: cfg,
            policy: Asynchrony::Bounded {
                tau,
                quorum: Quorum::AtLeast(nodes - 1),
            },
            ..Default::default()
        })
        .run(&mut cluster, None, &StopRule::iters(60));

        // same tolerance the synchronous suite pins
        let gap = (run.f - fstar) / fstar;
        assert!(gap < 1e-4, "τ={tau}: gap={gap}");
        // monotone descent: every committed direction passed a descent
        // gate (θ-cone quorum direction or the synchronous fallback)
        for k in 1..run.trace.points.len() {
            assert!(
                run.trace.points[k].f <= run.trace.points[k - 1].f + 1e-10,
                "τ={tau}: f increased at iter {k}"
            );
        }
        // the staleness bound held for everything the master combined
        assert!(
            cluster.ledger.staleness_hist.len() <= tau + 1,
            "τ={tau}: contribution older than the bound: {:?}",
            cluster.ledger.staleness_hist
        );
        assert!(cluster.ledger.async_rounds > 0);
    }
}

#[test]
fn adversarial_split_fires_safeguard_fallback() {
    // label-sorted shards: each node's local approximation pulls
    // toward its own class, so re-based stale directions from one
    // round back quickly leave a tight θ cone around the current −gʳ
    let data = make_data(7);
    let nodes = 3;
    let mut order: Vec<usize> = (0..data.n_examples()).collect();
    order.sort_by(|&a, &b| {
        data.y[a]
            .partial_cmp(&data.y[b])
            .unwrap()
            .then(a.cmp(&b))
    });
    let chunk = order.len().div_ceil(nodes);
    let assignment: Vec<Vec<usize>> =
        order.chunks(chunk).map(|c| c.to_vec()).collect();
    let part = Partition { assignment };
    let mut cluster =
        Cluster::partition_with(data, &part, CostModel::default());
    cluster.threads = 1;

    // quorum of 1: after round 0 every node always has an immediately
    // available *stale* contribution, so combines are stale-dominated
    let run = AsyncFsDriver::new(AsyncFsConfig {
        fs: FsConfig {
            lam: 0.5,
            epochs: 2,
            safeguard: Safeguard::from_degrees(5.0),
            ..Default::default()
        },
        policy: Asynchrony::Bounded { tau: 3, quorum: Quorum::AtLeast(1) },
        ..Default::default()
    })
    .run(&mut cluster, None, &StopRule::iters(15));

    assert!(
        cluster.ledger.fallback_rounds >= 1,
        "no round fell back to the synchronous barrier direction: {}",
        cluster.ledger.staleness_profile()
    );
    // stale contributions really were combined (or at least attempted)
    let stale_total: usize =
        cluster.ledger.staleness_hist.iter().skip(1).sum();
    assert!(
        stale_total > 0,
        "quorum never consumed a stale contribution: {}",
        cluster.ledger.staleness_profile()
    );
    assert!(
        cluster.ledger.staleness_hist.len() <= 4,
        "staleness bound violated: {:?}",
        cluster.ledger.staleness_hist
    );
    // ...and the run still descends: fallback rounds keep the paper's
    // guarantee intact
    let pts = &run.trace.points;
    assert!(pts.last().unwrap().f < pts[0].f, "failed to descend");
}

#[test]
fn async_run_records_solver_lanes_and_staleness() {
    let nodes = 4;
    let mut cluster = make_cluster(nodes, 11);
    cluster.set_profile(NodeProfile::with_straggler(nodes, 0, 3.0));
    let _ = AsyncFsDriver::new(AsyncFsConfig {
        fs: fs_config(),
        policy: Asynchrony::Bounded {
            tau: 2,
            quorum: Quorum::AtLeast(nodes - 1),
        },
        ..Default::default()
    })
    .run(&mut cluster, None, &StopRule::iters(6));

    let events = cluster.engine.events();
    assert!(events.iter().any(|e| e.label == "async_solve"));
    assert!(events.iter().any(|e| e.label == "async_reduce"));
    assert!(events
        .iter()
        .any(|e| e.label == "async_arrival" && e.staleness.is_some()));
    // the timeline export carries the staleness field
    let json = cluster.engine.timeline_json().to_json(0);
    assert!(json.contains("\"staleness\""), "{json}");
    assert!(cluster.ledger.async_rounds > 0);
    assert!(cluster.ledger.staleness_hist.iter().sum::<usize>() > 0);
}
