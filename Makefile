# Tier-1 verification — the invariant every PR must keep green.
# Runs fully offline: no registry dependencies, no xla_extension .so
# (the PJRT runtime is gated behind the off-by-default `xla` feature).
# The invariant lint rides along: a tree that violates the compact/
# deterministic-core rules fails verify even before CI sees it.
verify: lint-invariants
	cargo build --release && cargo test -q

# Repo-invariant static check (lint/ — the pallas-lint workspace
# member): no O(d) master allocations, no wall clocks in virtual-clock
# code, no unordered iteration near reductions, ledger-paired comm
# calls, no steady-state allocation in scratch-served bodies, and
# SAFETY-documented Miri-covered unsafe. Exits nonzero on any finding
# that isn't covered by a justified `// lint: allow(...)`.
lint-invariants:
	cargo run --release --package pallas-lint -- rust/src

test:
	cargo test

bench:
	cargo bench

# Fast bench smoke for CI: the sparse wire pipeline, the
# compact-vs-full inner solve (asserts compact is strictly faster and
# ε-equivalent), the pipelined-schedule bench (asserts pipelined
# makespan ≤ barrier everywhere and strictly lower on the straggler
# scenario, with bit-identical arithmetic), the async-FS bench
# (asserts the bounded-staleness quorum's makespan-to-ε strictly beats
# the pipelined schedule on the straggler) and the master_side bench
# (asserts the union-support compact master is strictly faster per
# round than the dense master at d = 5M and 50M with ε-identical
# traces — the 50M case doubles as the O(τ·|U|)-memory proof). Each
# bench writes a machine-readable BENCH_<name>.json that CI uploads as
# an artifact.
bench-smoke:
	cargo bench --bench sparse_grad
	cargo bench --bench compact_solve
	cargo bench --bench pipeline
	cargo bench --bench async_fs
	cargo bench --bench master_side

# Flight-recorder smoke (the CI `telemetry` job): a seeded async+fault
# run streams one typed JSONL record per outer round into run.jsonl,
# then the offline reader validates the stream (manifest header first,
# matching schema, one record per round in order). The same stream
# feeds `--report-from run.jsonl` for the full offline report and
# `--report-from a.jsonl b.jsonl` for run diffing.
telemetry:
	cargo run --release -p psgd -- train --method fs --async-fs \
		--nodes 5 --examples 400 --features 2000 --iters 12 \
		--lambda 0.5 --threads 1 --fault seeded \
		--metrics-out run.jsonl
	cargo run --release -p psgd -- --report-from run.jsonl --check

# Seeded fleet-weather chaos gate (the CI `chaos` job): a 3-seed ×
# {crash, flap, degrade} matrix of the async FS driver under fault
# injection — every cell must reach the clean run's objective target,
# record its scripted fault activity on the Ledger, and the replay
# gate must reproduce one seed's fault timeline + iterate bitwise.
# The speculation bench rides along: speculative lanes must strictly
# beat plain async to the same ε on the straggler and chaos matrices,
# the spec-off ledger must stay clean, and the adaptive (τ, q) trace
# must replay bit-identically. The link_weather bench gates the
# link-level story: uniform links bit-identical to none, retry/reroute
# strictly beating waiting out dead links by absolute virtual seconds,
# partitions healing through the certified fallback, and bitwise
# link-seed replay. Writes BENCH_fault_tolerance.json,
# BENCH_speculation.json and BENCH_link_weather.json for the artifact
# upload.
chaos:
	cargo bench --bench fault_tolerance
	cargo bench --bench speculation
	cargo bench --bench link_weather

fmt-check:
	cargo fmt --check

# blocking in CI: new lints fail PRs
clippy:
	cargo clippy --all-targets -- -D warnings

# AOT-compile the JAX/Pallas kernels to artifacts/*.hlo.txt for the
# xla-feature runtime (needs the python toolchain; not part of tier-1).
artifacts:
	python3 python/compile/aot.py --out artifacts

.PHONY: verify test bench bench-smoke chaos telemetry fmt-check clippy \
	artifacts lint-invariants
