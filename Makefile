# Tier-1 verification — the invariant every PR must keep green.
# Runs fully offline: no registry dependencies, no xla_extension .so
# (the PJRT runtime is gated behind the off-by-default `xla` feature).
verify:
	cargo build --release && cargo test -q

test:
	cargo test

bench:
	cargo bench

# Fast bench smoke for CI: the sparse wire pipeline, the
# compact-vs-full inner solve (asserts compact is strictly faster and
# ε-equivalent) and the pipelined-schedule bench (asserts pipelined
# makespan ≤ barrier everywhere and strictly lower on the straggler
# scenario, with bit-identical arithmetic).
bench-smoke:
	cargo bench --bench sparse_grad
	cargo bench --bench compact_solve
	cargo bench --bench pipeline

fmt-check:
	cargo fmt --check

clippy:
	cargo clippy --all-targets

# AOT-compile the JAX/Pallas kernels to artifacts/*.hlo.txt for the
# xla-feature runtime (needs the python toolchain; not part of tier-1).
artifacts:
	python3 python/compile/aot.py --out artifacts

.PHONY: verify test bench bench-smoke fmt-check clippy artifacts
