# Tier-1 verification — the invariant every PR must keep green.
# Runs fully offline: no registry dependencies, no xla_extension .so
# (the PJRT runtime is gated behind the off-by-default `xla` feature).
verify:
	cargo build --release && cargo test -q

test:
	cargo test

bench:
	cargo bench

# AOT-compile the JAX/Pallas kernels to artifacts/*.hlo.txt for the
# xla-feature runtime (needs the python toolchain; not part of tier-1).
artifacts:
	python3 python/compile/aot.py --out artifacts

.PHONY: verify test bench artifacts
