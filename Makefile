# Tier-1 verification — the invariant every PR must keep green.
# Runs fully offline: no registry dependencies, no xla_extension .so
# (the PJRT runtime is gated behind the off-by-default `xla` feature).
verify:
	cargo build --release && cargo test -q

test:
	cargo test

bench:
	cargo bench

# Fast bench smoke for CI: the sparse wire pipeline and the
# compact-vs-full inner solve (the latter asserts compact is strictly
# faster and ε-equivalent, so a perf/correctness regression fails CI).
bench-smoke:
	cargo bench --bench sparse_grad
	cargo bench --bench compact_solve

fmt-check:
	cargo fmt --check

# AOT-compile the JAX/Pallas kernels to artifacts/*.hlo.txt for the
# xla-feature runtime (needs the python toolchain; not part of tier-1).
artifacts:
	python3 python/compile/aot.py --out artifacts

.PHONY: verify test bench bench-smoke fmt-check artifacts
