// fixture: linted as linalg/csr.rs — SAFETY within the previous four
// comment lines, inside a Miri-covered module
pub fn good(w: &[f64], c: usize) -> f64 {
    // SAFETY: c < w.len() is enforced by push_row at construction
    // time, so the unchecked read cannot go out of bounds.
    unsafe { *w.get_unchecked(c) }
}
