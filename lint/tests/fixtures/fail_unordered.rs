// fixture: linted as objective/loss.rs — unordered containers fire
use std::collections::HashMap;

pub fn bad(keys: &[u32]) -> usize {
    let mut m: HashMap<u32, f64> = HashMap::new();
    for &k in keys {
        *m.entry(k).or_insert(0.0) += 1.0;
    }
    let s: std::collections::HashSet<u32> = keys.iter().copied().collect();
    m.len() + s.len()
}
