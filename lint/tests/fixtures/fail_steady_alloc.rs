// fixture: linted as algo/fs.rs — allocation inside a scratch-served
// per-round body must fire
pub fn bad(cluster: &mut Cluster, g: &[f64]) -> f64 {
    cluster.map_each_scratch_ctrl(|node, scratch| {
        let mut tmp = Vec::new();
        tmp.extend_from_slice(g);
        let copy = g.to_vec();
        let snapshot = scratch.buf.clone();
        node.consume(&tmp, &copy, &snapshot);
    });
    cluster.map_reduce_scalars_scratch(|node, s| {
        let pad = vec![0.0; 4];
        node.score(s) + pad.len() as f64
    })
}
