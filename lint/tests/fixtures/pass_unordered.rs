// fixture: linted as objective/loss.rs — BTreeMap iterates in key
// order, so reductions stay deterministic
use std::collections::BTreeMap;

pub fn good(keys: &[u32]) -> usize {
    let mut m: BTreeMap<u32, f64> = BTreeMap::new();
    for &k in keys {
        *m.entry(k).or_insert(0.0) += 1.0;
    }
    m.len()
}
