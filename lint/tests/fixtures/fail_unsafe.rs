// fixture: linted as algo/fs.rs — unsafe without SAFETY fires once,
// and unsafe outside the Miri-covered modules fires regardless
pub fn bad(w: &[f64], c: usize) -> f64 {
    unsafe { *w.get_unchecked(c) }
}

pub fn bad_even_with_comment(w: &[f64], c: usize) -> f64 {
    // SAFETY: c < w.len() checked by the caller
    unsafe { *w.get_unchecked(c) }
}
