// fixture: linted as algo/fs.rs — cluster-named receivers (including
// multiline method chains) thread the ledger and stay clean
pub fn good(cluster: &mut Cluster, parts: &[Vec<f64>]) -> Vec<f64> {
    let a = cluster.reduce_parts(parts);
    let b = self.cluster.map_allreduce_vec(parts);
    let c = cluster
        .async_quorum_reduce_sparse(parts);
    cluster.charge_scalar_round(1);
    merge(a, b, c)
}
