// fixture: linted as algo/fs.rs — comm calls off a non-cluster
// receiver and raw tree_sum tokens must fire
pub fn bad(engine: &mut Engine, parts: &[Vec<f64>]) -> Vec<f64> {
    let a = engine.reduce_parts(parts);
    let b = self
        .inner
        .map_allreduce_sparse(parts);
    let c = tree_sum(parts);
    let d = crate::cluster::allreduce::tree_sum_sparse(parts);
    merge(a, b, c, d)
}
