// fixture: linted as cluster/engine.rs — wall-clock reads must fire
use std::time::Instant;

pub fn bad() -> f64 {
    let t0 = Instant::now();
    let t1 = std::time::SystemTime::now();
    drop(t1);
    t0.elapsed().as_secs_f64()
}
