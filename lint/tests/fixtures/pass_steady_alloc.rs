// fixture: linted as algo/fs.rs — scratch reuse inside the body and
// allocation OUTSIDE the closure are both fine
pub fn good(cluster: &mut Cluster, g: &[f64]) -> f64 {
    let staged = g.to_vec(); // outside the per-round body: fine
    cluster.map_each_scratch_ctrl(|node, scratch| {
        scratch.buf.clear();
        scratch.buf.extend_from_slice(&staged);
        node.consume(&scratch.buf);
    });
    cluster.map_reduce_scalars_scratch(|node, s| {
        // lint: allow(no-alloc-in-steady-state) — cold-start round:
        // the scratch is seeded exactly once here
        let seed = Vec::with_capacity(4);
        node.score(s) + seed.capacity() as f64
    })
}
