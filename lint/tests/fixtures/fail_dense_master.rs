// fixture: linted as algo/fs.rs — every O(d) allocation here must fire
pub fn bad(dim: usize, d: usize) -> Vec<f64> {
    let g = vec![0.0f64; dim];
    let mut h: Vec<f64> = Vec::with_capacity(d);
    h.extend_from_slice(&g);
    let z = vec![0u32; g.len().min(dim)]; // count expr not dim-shaped: ok
    assert_eq!(z.len(), h.capacity().min(dim));
    g
}

pub struct P {
    pub dim: usize,
}

pub fn bad_field(p: &P) -> Vec<f64> {
    vec![1.0; p.dim]
}
