// fixture: linted as cluster/engine.rs — virtual clocks only; the
// word Instant may appear in comments and strings without firing
pub fn good(clock: &mut f64, dur: f64) -> f64 {
    // an Instant would be wrong here: time flows through the engine
    let label = "Instant";
    assert_eq!(label.len(), 7);
    *clock += dur;
    *clock
}
