// fixture: linted as algo/fs.rs — compact shapes, allows, and the
// #[cfg(test)] exemption must all stay clean
pub fn good(u: usize, nnz: usize) -> Vec<f64> {
    let v = vec![0.0f64; u]; // |U|-sized: fine
    let mut idx: Vec<u32> = Vec::with_capacity(nnz);
    idx.push(0);
    v
}

pub fn justified(dim: usize) -> Vec<f64> {
    // lint: allow(no-dense-master) — wire payload: this buffer IS the
    // dense message the reduction moves
    vec![0.0; dim]
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_scaffolding_may_be_dense() {
        let dim = 8;
        let w = vec![1.0f64; dim];
        assert_eq!(w.len(), dim);
    }
}
