//! Fixture-driven checks: one failing and one passing fixture per
//! rule (the fixtures live under `tests/fixtures/` as data — cargo
//! does not compile `tests/` subdirectories), plus the meta-test that
//! the shipped `rust/src` tree itself is lint-clean. Each fixture is
//! linted under an explicit relpath because the path decides rule
//! scope.

use pallas_lint::{lint_source, lint_tree, Finding};
use std::path::Path;

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

fn rules(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

#[test]
fn dense_master_fail_fixture_fires() {
    let hits = lint_source("algo/fs.rs", &fixture("fail_dense_master.rs"));
    assert_eq!(hits.len(), 3, "{hits:#?}");
    assert!(rules(&hits).iter().all(|r| *r == "no-dense-master"));
    let lines: Vec<usize> = hits.iter().map(|f| f.line).collect();
    assert!(lines.contains(&3), "vec![_; dim] missed: {lines:?}");
    assert!(lines.contains(&4), "with_capacity(d) missed: {lines:?}");
    assert!(lines.contains(&16), "vec![_; p.dim] missed: {lines:?}");
}

#[test]
fn dense_master_pass_fixture_is_clean() {
    let hits =
        lint_source("algo/fs.rs", &fixture("pass_dense_master.rs"));
    assert!(hits.is_empty(), "{hits:#?}");
}

#[test]
fn dense_master_scope_is_limited_to_driver_files() {
    // the same dense code outside the protected file list is fine
    let hits =
        lint_source("linalg/dense.rs", &fixture("fail_dense_master.rs"));
    assert!(hits.is_empty(), "{hits:#?}");
}

#[test]
fn wall_clock_fail_fixture_fires() {
    let hits =
        lint_source("cluster/engine.rs", &fixture("fail_wall_clock.rs"));
    assert_eq!(hits.len(), 3, "{hits:#?}");
    assert!(rules(&hits).iter().all(|r| *r == "no-wall-clock"));
}

#[test]
fn wall_clock_pass_fixture_is_clean() {
    let hits =
        lint_source("cluster/engine.rs", &fixture("pass_wall_clock.rs"));
    assert!(hits.is_empty(), "{hits:#?}");
}

#[test]
fn unordered_fail_fixture_fires() {
    let hits =
        lint_source("objective/loss.rs", &fixture("fail_unordered.rs"));
    assert_eq!(hits.len(), 3, "{hits:#?}");
    assert!(rules(&hits)
        .iter()
        .all(|r| *r == "no-unordered-iteration"));
}

#[test]
fn unordered_pass_fixture_is_clean() {
    let hits =
        lint_source("objective/loss.rs", &fixture("pass_unordered.rs"));
    assert!(hits.is_empty(), "{hits:#?}");
}

#[test]
fn ledger_fail_fixture_fires() {
    let hits = lint_source("algo/fs.rs", &fixture("fail_ledger.rs"));
    assert_eq!(hits.len(), 4, "{hits:#?}");
    assert!(rules(&hits).iter().all(|r| *r == "ledger-pairing"));
    // the multiline-chain receiver (`self\n.inner\n.method(`) must be
    // resolved across the line break, not skipped
    assert!(
        hits.iter().any(|f| f.msg.contains("map_allreduce_sparse")),
        "{hits:#?}"
    );
}

#[test]
fn ledger_pass_fixture_is_clean() {
    let hits = lint_source("algo/fs.rs", &fixture("pass_ledger.rs"));
    assert!(hits.is_empty(), "{hits:#?}");
}

#[test]
fn steady_alloc_fail_fixture_fires() {
    let hits =
        lint_source("algo/fs.rs", &fixture("fail_steady_alloc.rs"));
    assert_eq!(hits.len(), 4, "{hits:#?}");
    assert!(rules(&hits)
        .iter()
        .all(|r| *r == "no-alloc-in-steady-state"));
}

#[test]
fn steady_alloc_pass_fixture_is_clean() {
    let hits =
        lint_source("algo/fs.rs", &fixture("pass_steady_alloc.rs"));
    assert!(hits.is_empty(), "{hits:#?}");
}

#[test]
fn unsafe_fail_fixture_fires() {
    let hits = lint_source("algo/fs.rs", &fixture("fail_unsafe.rs"));
    // first unsafe: missing SAFETY + wrong module; second: SAFETY
    // present but still the wrong module
    assert_eq!(hits.len(), 3, "{hits:#?}");
    assert!(rules(&hits).iter().all(|r| *r == "unsafe-contract"));
    assert_eq!(
        hits.iter()
            .filter(|f| f.msg.contains("SAFETY"))
            .count(),
        1,
        "{hits:#?}"
    );
}

#[test]
fn unsafe_pass_fixture_is_clean() {
    let hits = lint_source("linalg/csr.rs", &fixture("pass_unsafe.rs"));
    assert!(hits.is_empty(), "{hits:#?}");
}

#[test]
fn allow_without_reason_is_ignored() {
    let src = "// lint: allow(no-wall-clock)\nlet t = Instant::now();\n";
    let hits = lint_source("algo/fs.rs", src);
    assert_eq!(hits.len(), 1, "{hits:#?}");
}

#[test]
fn allow_file_covers_the_whole_file() {
    let src = "// lint: allow-file(no-wall-clock) — simulation seam\n\
               let t = Instant::now();\nlet u = Instant::now();\n";
    assert!(lint_source("algo/fs.rs", src).is_empty());
}

#[test]
fn shipped_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../rust/src");
    let findings = lint_tree(&root).expect("scan rust/src");
    assert!(
        findings.is_empty(),
        "shipped tree has lint findings:\n{}",
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
