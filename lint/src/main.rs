//! `pallas-lint <dir>...` — lint every `.rs` file under each given
//! directory (default `rust/src`) against the repo invariants and exit
//! nonzero if any finding survives the allow comments. Wired into
//! `make lint-invariants`, which `make verify` and CI both run.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut roots: Vec<String> = std::env::args().skip(1).collect();
    if roots.is_empty() {
        roots.push("rust/src".to_string());
    }
    let mut findings = Vec::new();
    for root in &roots {
        match pallas_lint::lint_tree(Path::new(root)) {
            Ok(f) => findings.extend(f),
            Err(e) => {
                eprintln!("pallas-lint: cannot scan {root}: {e}");
                return ExitCode::from(2);
            }
        }
    }
    for f in &findings {
        println!("{f}");
    }
    println!("-- {} finding(s)", findings.len());
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
