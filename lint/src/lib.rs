//! `pallas-lint` — static checker for the repo-specific invariants the
//! compiler cannot see (see the "Invariants" section of
//! `rust/src/lib.rs` for the rationale of each rule):
//!
//! 1. `no-dense-master` — no `vec![_; dim]` / `with_capacity(dim)`
//!    O(d) allocations in the outer-loop driver files.
//! 2. `no-wall-clock` — `Instant`/`SystemTime` banned where timing
//!    must flow through the engine's virtual clocks.
//! 3. `no-unordered-iteration` — `HashMap`/`HashSet` banned in code
//!    feeding reductions or wire payloads.
//! 4. `ledger-pairing` — comm methods only on a cluster handle; raw
//!    `tree_sum` banned outside `cluster/`.
//! 5. `no-alloc-in-steady-state` — no allocation inside the per-round
//!    closure bodies `NodeScratch` serves.
//! 6. `unsafe-contract` — `unsafe` needs a `// SAFETY:` comment and a
//!    Miri-covered module.
//!
//! The scanner is a hand-rolled lexer (no syn — the build must stay
//! offline-dependency-free): it splits each file into per-line *code*
//! (comments and string/char-literal bodies blanked) and per-line
//! *comment text*, masks `#[cfg(test)] mod` bodies, and honors the
//! escape hatches
//! `// lint: allow(<rule>[, <rule>]) — <reason>` (this line or carried
//! onto the next code line) and
//! `// lint: allow-file(<rule>) — <reason>` (whole file). The reason
//! is mandatory: an allow without one is ignored.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Files whose `unsafe` blocks are exercised by the Miri CI job.
const MIRI_COVERED: [&str; 3] =
    ["linalg/csr.rs", "linalg/sparse.rs", "linalg/dense.rs"];

/// Outer-loop driver files rule 1 protects.
const DENSE_MASTER_FILES: [&str; 5] = [
    "algo/fs.rs",
    "algo/async_fs.rs",
    "algo/param_mix.rs",
    "algo/common.rs",
    "algo/theory.rs",
];

/// Ledger-threading comm methods (rule 4): callable only on a
/// `cluster`-named receiver.
const COMM_METHODS: [&str; 24] = [
    "reduce_parts",
    "reduce_parts_ctrl",
    "reduce_parts_sparse",
    "reduce_parts_sparse_ctrl",
    "reduce_parts_members",
    "reduce_parts_ctrl_members",
    "reduce_parts_sparse_members",
    "reduce_parts_sparse_ctrl_members",
    "map_reduce_vec",
    "map_allreduce_vec",
    "map_reduce_sparse",
    "map_allreduce_sparse",
    "map_reduce_scalars",
    "map_reduce_scalars_scratch",
    "map_reduce_scalars_scratch_members",
    "broadcast_vec",
    "broadcast_support",
    "broadcast_master",
    "async_quorum_reduce",
    "async_quorum_reduce_sparse",
    "async_quorum_reduce_members",
    "async_quorum_reduce_sparse_members",
    "charge_scalar_round",
    "charge_scalar_round_members",
];

/// The scratch-served per-round phases rule 5 keeps allocation-free.
const SCRATCH_PHASES: [&str; 7] = [
    ".map_each_scratch_ctrl(",
    ".map_each_scratch(",
    ".map_each_scratch_members(",
    ".map_each_scratch_ctrl_members(",
    ".map_reduce_scalars_scratch(",
    ".map_reduce_scalars_scratch_members(",
    ".map_nodes_timed(",
];

/// Allocation/copy tokens banned inside those bodies.
const BANNED_ALLOC: [&str; 5] =
    ["Vec::new", "Vec::with_capacity", "vec![", ".to_vec(", ".clone("];

/// One rule violation at a source line.
#[derive(Debug, Clone)]
pub struct Finding {
    /// path relative to the scanned root, `/`-separated
    pub file: String,
    /// 1-based line number
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

// ---------------------------------------------------------------------
// lexer: split source into per-line code / per-line comment text
// ---------------------------------------------------------------------

#[derive(PartialEq, Clone, Copy)]
enum LexState {
    Code,
    LineComment,
    BlockComment,
    Str,
    RawStr,
    CharLit,
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Blank comments and string/char bodies out of the code stream while
/// capturing comment text, both per line. Handles nested block
/// comments, raw strings with `#` fences, and the `'a` lifetime vs
/// `'a'` char-literal ambiguity (a quote is a char literal when it is
/// escaped or closes two characters later).
fn strip_source(src: &str) -> (Vec<String>, Vec<String>) {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut code_lines = Vec::new();
    let mut comment_lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut st = LexState::Code;
    let mut block_depth = 0usize;
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if st == LexState::LineComment {
                st = LexState::Code;
            }
            code_lines.push(std::mem::take(&mut code));
            comment_lines.push(std::mem::take(&mut comment));
            i += 1;
            continue;
        }
        match st {
            LexState::Code => {
                if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                    st = LexState::LineComment;
                    i += 2;
                    continue;
                }
                if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    st = LexState::BlockComment;
                    block_depth = 1;
                    i += 2;
                    continue;
                }
                if c == '"' {
                    st = LexState::Str;
                    code.push(' ');
                    i += 1;
                    continue;
                }
                if c == 'r' && (i == 0 || !is_ident_char(chars[i - 1])) {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && chars[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && chars[j] == '"' {
                        st = LexState::RawStr;
                        raw_hashes = h;
                        code.push(' ');
                        i = j + 1;
                        continue;
                    }
                }
                if c == '\'' {
                    if i + 1 < n && chars[i + 1] == '\\' {
                        st = LexState::CharLit;
                        i += 2;
                        continue;
                    }
                    if i + 2 < n && chars[i + 2] == '\'' {
                        st = LexState::CharLit;
                        i += 1;
                        continue;
                    }
                    // lifetime: keep the quote in the code stream
                    code.push(c);
                    i += 1;
                    continue;
                }
                code.push(c);
                i += 1;
            }
            LexState::LineComment => {
                comment.push(c);
                i += 1;
            }
            LexState::BlockComment => {
                if c == '*' && i + 1 < n && chars[i + 1] == '/' {
                    block_depth -= 1;
                    i += 2;
                    if block_depth == 0 {
                        st = LexState::Code;
                    }
                    continue;
                }
                if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    block_depth += 1;
                    i += 2;
                    continue;
                }
                comment.push(c);
                i += 1;
            }
            LexState::Str => {
                if c == '\\' {
                    i += 2;
                    continue;
                }
                if c == '"' {
                    st = LexState::Code;
                }
                i += 1;
            }
            LexState::RawStr => {
                if c == '"'
                    && i + raw_hashes < n
                    && chars[i + 1..i + 1 + raw_hashes]
                        .iter()
                        .all(|&h| h == '#')
                {
                    st = LexState::Code;
                    i += 1 + raw_hashes;
                    continue;
                }
                i += 1;
            }
            LexState::CharLit => {
                if c == '\\' {
                    i += 2;
                    continue;
                }
                if c == '\'' {
                    st = LexState::Code;
                }
                i += 1;
            }
        }
    }
    code_lines.push(code);
    comment_lines.push(comment);
    (code_lines, comment_lines)
}

/// Token-boundary substring match (identifiers don't run into `tok`).
fn has_token(line: &str, tok: &str) -> bool {
    let bytes = line.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = line[start..].find(tok) {
        let p = start + pos;
        let before_ok = p == 0 || !is_ident_byte(bytes[p - 1]);
        let after = p + tok.len();
        let after_ok = after >= bytes.len() || !is_ident_byte(bytes[after]);
        if before_ok && after_ok {
            return true;
        }
        start = p + 1;
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Per-line mask of `#[cfg(test)] mod ... { }` bodies (brace-depth
/// tracked on the stripped code, so strings/comments can't confuse it).
fn test_mask(code_lines: &[String]) -> Vec<bool> {
    let mut mask = Vec::with_capacity(code_lines.len());
    let mut pending = false; // saw #[cfg(test)], waiting for `mod`
    let mut waiting = false; // saw mod, waiting for its `{`
    let mut in_test = false;
    let mut depth = 0i64;
    let mut test_depth = 0i64;
    for line in code_lines {
        let mut line_test = in_test || waiting;
        if pending && has_token(line, "mod") {
            waiting = true;
            pending = false;
            line_test = true;
        }
        for ch in line.chars() {
            if waiting && ch == '{' {
                in_test = true;
                test_depth = depth;
                waiting = false;
                depth += 1;
                line_test = true;
                continue;
            }
            if ch == '{' {
                depth += 1;
            } else if ch == '}' {
                depth -= 1;
                if in_test && depth == test_depth {
                    in_test = false;
                }
            }
        }
        if line.replace(' ', "").contains("#[cfg(test)]") {
            pending = true;
            line_test = true;
        }
        mask.push(line_test);
    }
    mask
}

// ---------------------------------------------------------------------
// allow-comment parsing
// ---------------------------------------------------------------------

/// `lint: allow(...)` / `lint: allow-file(...)` occurrences in one
/// comment line. The reason after the closing paren is mandatory.
fn parse_allows(com: &str) -> (Vec<String>, Vec<String>) {
    let mut line_rules = Vec::new();
    let mut file_rules = Vec::new();
    let mut idx = 0usize;
    while let Some(rel) = com[idx..].find("lint:") {
        let p = idx + rel;
        idx = p + 5;
        let rest = com[p + 5..].trim_start();
        let (is_file, body) =
            if let Some(r) = rest.strip_prefix("allow-file(") {
                (true, r)
            } else if let Some(r) = rest.strip_prefix("allow(") {
                (false, r)
            } else {
                continue;
            };
        let Some(close) = body.find(')') else { break };
        let reason = body[close + 1..].trim_matches(|c: char| {
            c.is_whitespace() || matches!(c, '-' | '—' | ':' | '·')
        });
        if reason.is_empty() {
            continue; // no justification, no exemption
        }
        for rule in body[..close].split(',') {
            let rule = rule.trim();
            if !rule.is_empty() {
                if is_file {
                    file_rules.push(rule.to_string());
                } else {
                    line_rules.push(rule.to_string());
                }
            }
        }
    }
    (line_rules, file_rules)
}

/// Per-line allow sets (allows on comment-only lines carry forward to
/// the next code line) and the file-wide allow set.
fn collect_allows(
    code_lines: &[String],
    comment_lines: &[String],
) -> (Vec<Vec<String>>, Vec<String>) {
    let mut line_allows: Vec<Vec<String>> =
        vec![Vec::new(); code_lines.len()];
    let mut file_allows = Vec::new();
    let mut carry: Vec<String> = Vec::new();
    for (i, (code, com)) in
        code_lines.iter().zip(comment_lines).enumerate()
    {
        let (found, file_found) = parse_allows(com);
        file_allows.extend(file_found);
        if code.trim().is_empty() {
            carry.extend(found);
        } else {
            line_allows[i].extend(carry.drain(..));
            line_allows[i].extend(found);
        }
    }
    (line_allows, file_allows)
}

// ---------------------------------------------------------------------
// text helpers over the joined code stream
// ---------------------------------------------------------------------

/// 0-based line index of a byte offset into the joined code text.
fn line_of(line_starts: &[usize], offset: usize) -> usize {
    match line_starts.binary_search(&offset) {
        Ok(i) => i,
        Err(i) => i - 1,
    }
}

/// Byte offset of the delimiter matching `text[start]`.
fn find_matching(
    text: &str,
    start: usize,
    open: u8,
    close: u8,
) -> Option<usize> {
    let bytes = text.as_bytes();
    let mut depth = 0i64;
    for (k, &b) in bytes.iter().enumerate().skip(start) {
        if b == open {
            depth += 1;
        } else if b == close {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

fn dim_shaped(expr: &str) -> bool {
    let e = expr.trim();
    e == "d" || e == "dim" || e.ends_with(".dim")
}

/// For `vec![ ... ]` starting with the `[` at `lb`: the count
/// expression after the last top-level `;`, if the macro uses the
/// `vec![elem; count]` form.
fn vec_count_expr(text: &str, lb: usize) -> Option<(usize, usize)> {
    let bytes = text.as_bytes();
    let (mut sq, mut par, mut br) = (0i64, 0i64, 0i64);
    let mut last_semi: Option<usize> = None;
    for (k, &b) in bytes.iter().enumerate().skip(lb) {
        match b {
            b'[' => sq += 1,
            b']' => {
                sq -= 1;
                if sq == 0 {
                    return last_semi.map(|s| (s + 1, k));
                }
            }
            b'(' => par += 1,
            b')' => par -= 1,
            b'{' => br += 1,
            b'}' => br -= 1,
            b';' if sq == 1 && par == 0 && br == 0 => last_semi = Some(k),
            _ => {}
        }
    }
    None
}

// ---------------------------------------------------------------------
// the rule engine
// ---------------------------------------------------------------------

struct FileLint<'a> {
    relpath: &'a str,
    code_lines: Vec<String>,
    comment_lines: Vec<String>,
    mask: Vec<bool>,
    line_allows: Vec<Vec<String>>,
    file_allows: Vec<String>,
    text: String,
    line_starts: Vec<usize>,
    findings: Vec<Finding>,
}

impl<'a> FileLint<'a> {
    fn new(relpath: &'a str, src: &str) -> FileLint<'a> {
        let (code_lines, comment_lines) = strip_source(src);
        let mask = test_mask(&code_lines);
        let (line_allows, file_allows) =
            collect_allows(&code_lines, &comment_lines);
        let text = code_lines.join("\n");
        let mut line_starts = vec![0usize];
        for (off, b) in text.bytes().enumerate() {
            if b == b'\n' {
                line_starts.push(off + 1);
            }
        }
        FileLint {
            relpath,
            code_lines,
            comment_lines,
            mask,
            line_allows,
            file_allows,
            text,
            line_starts,
            findings: Vec::new(),
        }
    }

    fn report(&mut self, rule: &'static str, line_idx: usize, msg: String) {
        if self.mask.get(line_idx).copied().unwrap_or(false) {
            return;
        }
        if self.file_allows.iter().any(|r| r == rule) {
            return;
        }
        if let Some(allows) = self.line_allows.get(line_idx) {
            if allows.iter().any(|r| r == rule) {
                return;
            }
        }
        self.findings.push(Finding {
            file: self.relpath.to_string(),
            line: line_idx + 1,
            rule,
            msg,
        });
    }

    fn in_algo(&self) -> bool {
        self.relpath.starts_with("algo/")
    }

    fn run(mut self) -> Vec<Finding> {
        self.rule_no_dense_master();
        self.rule_no_wall_clock();
        self.rule_no_unordered_iteration();
        self.rule_ledger_pairing();
        self.rule_no_alloc_in_steady_state();
        self.rule_unsafe_contract();
        self.findings
    }

    fn rule_no_dense_master(&mut self) {
        if !DENSE_MASTER_FILES.contains(&self.relpath) {
            return;
        }
        let text = self.text.clone();
        let mut start = 0usize;
        while let Some(rel) = text[start..].find("vec![") {
            let p = start + rel;
            start = p + 5;
            if let Some((lo, hi)) = vec_count_expr(&text, p + 4) {
                let expr = &text[lo..hi];
                if dim_shaped(expr) {
                    self.report(
                        "no-dense-master",
                        line_of(&self.line_starts, p),
                        format!(
                            "O(d) allocation `vec![..; {}]` in \
                             master-loop code",
                            expr.trim()
                        ),
                    );
                }
            }
        }
        let mut start = 0usize;
        while let Some(rel) = text[start..].find("with_capacity(") {
            let p = start + rel;
            let open = p + "with_capacity(".len() - 1;
            start = open + 1;
            if let Some(close) = find_matching(&text, open, b'(', b')') {
                if dim_shaped(&text[open + 1..close]) {
                    self.report(
                        "no-dense-master",
                        line_of(&self.line_starts, p),
                        "O(d) with_capacity in master-loop code".into(),
                    );
                }
            }
        }
    }

    fn rule_no_wall_clock(&mut self) {
        // faults.rs joins the list: a wall clock in the fault layer
        // would break the seeded-replay determinism contract; obs/
        // likewise — a timestamped telemetry record would make two
        // runs of one seed line-diff unequal; cost.rs carries the
        // seeded link profile, under the same replay contract
        if !(self.in_algo()
            || self.relpath == "cluster/engine.rs"
            || self.relpath == "cluster/allreduce.rs"
            || self.relpath == "cluster/faults.rs"
            || self.relpath == "cluster/cost.rs"
            || self.relpath.starts_with("obs/"))
        {
            return;
        }
        for i in 0..self.code_lines.len() {
            for tok in ["Instant", "SystemTime"] {
                if has_token(&self.code_lines[i], tok) {
                    self.report(
                        "no-wall-clock",
                        i,
                        format!(
                            "wall-clock `{tok}` in virtual-clock code"
                        ),
                    );
                }
            }
        }
    }

    fn rule_no_unordered_iteration(&mut self) {
        // obs/ is in scope: record fields and JSONL keys must come
        // out in a fixed order or recorded streams stop line-diffing
        if !(self.in_algo()
            || self.relpath.starts_with("cluster/")
            || self.relpath.starts_with("objective/")
            || self.relpath.starts_with("linalg/")
            || self.relpath.starts_with("obs/"))
        {
            return;
        }
        for i in 0..self.code_lines.len() {
            for tok in ["HashMap", "HashSet"] {
                if has_token(&self.code_lines[i], tok) {
                    self.report(
                        "no-unordered-iteration",
                        i,
                        format!(
                            "`{tok}` in reduction/wire-feeding code — \
                             iteration order must be deterministic"
                        ),
                    );
                }
            }
        }
    }

    fn rule_ledger_pairing(&mut self) {
        if !(self.in_algo()
            || self.relpath.starts_with("objective/")
            || self.relpath.starts_with("opt/"))
        {
            return;
        }
        for i in 0..self.code_lines.len() {
            for tok in ["tree_sum", "tree_sum_sparse"] {
                if has_token(&self.code_lines[i], tok) {
                    self.report(
                        "ledger-pairing",
                        i,
                        format!(
                            "raw `{tok}` bypasses the Cluster ledger"
                        ),
                    );
                }
            }
        }
        let text = self.text.clone();
        let bytes = text.as_bytes();
        let mut start = 0usize;
        while let Some(rel) = text[start..].find('.') {
            let p = start + rel;
            start = p + 1;
            // maximal [a-z_]+ method name followed by `(`
            let mut k = p + 1;
            while k < bytes.len()
                && (bytes[k].is_ascii_lowercase() || bytes[k] == b'_')
            {
                k += 1;
            }
            if k == p + 1 || k >= bytes.len() || bytes[k] != b'(' {
                continue;
            }
            let name = &text[p + 1..k];
            if !COMM_METHODS.contains(&name) {
                continue;
            }
            // receiver: skip whitespace backwards (method chains may
            // break the line before the dot), then take the ident/dot
            // run
            let mut j = p as i64 - 1;
            while j >= 0
                && (bytes[j as usize] == b' ' || bytes[j as usize] == b'\n')
            {
                j -= 1;
            }
            let recv_end = (j + 1) as usize;
            while j >= 0
                && (is_ident_byte(bytes[j as usize])
                    || bytes[j as usize] == b'.')
            {
                j -= 1;
            }
            let receiver = &text[(j + 1) as usize..recv_end];
            if !receiver.to_ascii_lowercase().contains("cluster") {
                self.report(
                    "ledger-pairing",
                    line_of(&self.line_starts, p),
                    format!(
                        "comm call `.{name}()` on `{receiver}` — not a \
                         ledger-threading cluster handle"
                    ),
                );
            }
        }
    }

    fn rule_no_alloc_in_steady_state(&mut self) {
        if !self.in_algo() {
            return;
        }
        let text = self.text.clone();
        for phase in SCRATCH_PHASES {
            let mut start = 0usize;
            while let Some(rel) = text[start..].find(phase) {
                let p = start + rel;
                start = p + phase.len();
                let open_paren = p + phase.len() - 1;
                let call_close =
                    find_matching(&text, open_paren, b'(', b')')
                        .unwrap_or(text.len());
                // the per-round closure: |args| { body } or |args| expr
                let Some(bar) = text[open_paren..call_close]
                    .find('|')
                    .map(|b| open_paren + b)
                else {
                    continue;
                };
                let body_start = if text.as_bytes().get(bar + 1)
                    == Some(&b'|')
                {
                    bar + 2
                } else {
                    let Some(bar2) = text[bar + 1..call_close]
                        .find('|')
                        .map(|b| bar + 1 + b)
                    else {
                        continue;
                    };
                    bar2 + 1
                };
                let mut k = body_start;
                let bytes = text.as_bytes();
                while k < text.len()
                    && (bytes[k] == b' ' || bytes[k] == b'\n')
                {
                    k += 1;
                }
                let body_end = if k < text.len() && bytes[k] == b'{' {
                    find_matching(&text, k, b'{', b'}')
                        .unwrap_or(call_close)
                } else {
                    call_close
                };
                let body = &text[body_start..body_end];
                for pat in BANNED_ALLOC {
                    let mut bpos = 0usize;
                    while let Some(q) = body[bpos..].find(pat) {
                        let q = bpos + q;
                        bpos = q + pat.len();
                        self.report(
                            "no-alloc-in-steady-state",
                            line_of(&self.line_starts, body_start + q),
                            format!(
                                "`{pat}` inside a scratch-served \
                                 per-round body"
                            ),
                        );
                    }
                }
            }
        }
    }

    fn rule_unsafe_contract(&mut self) {
        for i in 0..self.code_lines.len() {
            if !has_token(&self.code_lines[i], "unsafe") {
                continue;
            }
            let lo = i.saturating_sub(4);
            let near = self.comment_lines[lo..=i].join(" ");
            if !near.contains("SAFETY:") {
                self.report(
                    "unsafe-contract",
                    i,
                    "`unsafe` without a `// SAFETY:` comment".into(),
                );
            }
            if !MIRI_COVERED.contains(&self.relpath) {
                self.report(
                    "unsafe-contract",
                    i,
                    format!(
                        "`unsafe` in `{}` — not in the Miri-covered \
                         module list",
                        self.relpath
                    ),
                );
            }
        }
    }
}

/// Lint one file's source under its root-relative path (the path
/// decides which rules are in scope).
pub fn lint_source(relpath: &str, src: &str) -> Vec<Finding> {
    FileLint::new(relpath, src).run()
}

/// Recursively lint every `.rs` file under `root` (deterministic
/// order). `root` is typically `rust/src`.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = fs::read_to_string(&path)?;
        findings.extend(lint_source(&rel, &src));
    }
    findings.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
    Ok(findings)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexer_strips_comments_and_strings() {
        let src = "let a = \"vec![0.0; dim]\"; // vec![0.0; dim]\n\
                   /* block\nvec![0.0; dim] */ let b = 1;\n";
        let (code, com) = strip_source(src);
        assert!(!code.join("\n").contains("vec!"));
        assert!(com.join("\n").contains("vec![0.0; dim]"));
        assert!(code[1].contains("let b = 1;") || code[2].contains("let b"));
    }

    #[test]
    fn lexer_handles_lifetimes_and_raw_strings() {
        let src = "fn f<'a>(x: &'a str) { let c = 'y'; let r = r#\"hi\"#; }";
        let (code, _) = strip_source(src);
        assert!(code[0].contains("fn f<'a>(x: &'a str)"));
        assert!(!code[0].contains('y'), "{}", code[0]);
        assert!(!code[0].contains("hi"));
    }

    #[test]
    fn allow_requires_a_reason() {
        let src = "// lint: allow(no-dense-master)\nlet g = vec![0.0; dim];\n";
        let hits = lint_source("algo/fs.rs", src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        let src = "// lint: allow(no-dense-master) — wire payload\n\
                   let g = vec![0.0; dim];\n";
        assert!(lint_source("algo/fs.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_mod_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(dim: usize) {\n        \
                   let g = vec![0.0; dim];\n    }\n}\n";
        assert!(lint_source("algo/fs.rs", src).is_empty());
    }

    #[test]
    fn scope_is_path_dependent() {
        let src = "let t = Instant::now();\n";
        assert!(!lint_source("algo/fs.rs", src).is_empty());
        // the measured-threading sites live here: out of scope
        assert!(lint_source("cluster/mod.rs", src).is_empty());
        assert!(lint_source("util/timer.rs", src).is_empty());
    }

    #[test]
    fn fault_layer_is_wall_clock_free() {
        // the seeded-replay contract: no wall clocks in faults.rs
        let src = "let t = SystemTime::now();\n";
        let hits = lint_source("cluster/faults.rs", src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].rule, "no-wall-clock");
    }

    #[test]
    fn link_layer_is_wall_clock_free_and_ordered() {
        // the seeded link profile shares the replay contract
        let src = "let t = Instant::now();\n";
        let hits = lint_source("cluster/cost.rs", src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].rule, "no-wall-clock");
        // link-weather state feeds reductions: no unordered maps
        let src = "let cut: HashSet<usize> = HashSet::new();\n";
        let hits = lint_source("cluster/faults.rs", src);
        assert!(
            hits.iter().any(|h| h.rule == "no-unordered-iteration"),
            "{hits:?}"
        );
    }

    #[test]
    fn adaptive_controller_is_wall_clock_free() {
        // the (τ, q) controller must stay a pure ledger function —
        // seeded runs replay its decision trace bit-identically, so a
        // wall clock in algo/adapt.rs would break the replay contract
        let src = "let t = Instant::now();\n";
        let hits = lint_source("algo/adapt.rs", src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].rule, "no-wall-clock");
    }

    #[test]
    fn adaptive_controller_iterates_deterministically() {
        // tuning decisions feed the quorum deadline: a HashMap-backed
        // window statistic could flip (τ, q) between builds
        let src = "let m: HashMap<usize, f64> = HashMap::new();\n";
        let hits = lint_source("algo/adapt.rs", src);
        assert!(
            hits.iter().any(|f| f.rule == "no-unordered-iteration"),
            "{hits:?}"
        );
    }

    #[test]
    fn flight_recorder_is_wall_clock_free() {
        // recorded streams of one seed must line-diff equal: no
        // timestamps in the telemetry layer
        let src = "let t = Instant::now();\n";
        let hits = lint_source("obs/jsonl.rs", src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].rule, "no-wall-clock");
    }

    #[test]
    fn flight_recorder_emits_in_deterministic_order() {
        // a HashMap-backed registry would shuffle JSONL keys between
        // builds — obs/ is inside the no-unordered-iteration scope
        let src = "let m: HashMap<String, f64> = HashMap::new();\n";
        let hits = lint_source("obs/registry.rs", src);
        assert!(
            hits.iter().any(|f| f.rule == "no-unordered-iteration"),
            "{hits:?}"
        );
    }

    #[test]
    fn member_subset_phases_and_comm_calls_are_covered() {
        // elastic-membership comm must still thread the ledger...
        let src = "let d = engine.reduce_parts_sparse_members(&p, true, m);\n";
        let hits = lint_source("algo/async_fs.rs", src);
        assert!(
            hits.iter().any(|f| f.rule == "ledger-pairing"),
            "{hits:?}"
        );
        // ...and the members scratch bodies stay allocation-free
        let src = "cluster.map_each_scratch_members(m, |p, shard, s| {\n\
                   let z = Vec::new();\n});\n";
        let hits = lint_source("algo/async_fs.rs", src);
        assert!(
            hits.iter().any(|f| f.rule == "no-alloc-in-steady-state"),
            "{hits:?}"
        );
    }
}
