//! §Discussion (c) extension: automatic switching from FS (strong early
//! progress from approximate global views) to SQM (second-order
//! convergence near the optimum). Compares pure FS, pure SQM and the
//! auto-switching driver on the same cluster and prints where the
//! switch paid off.
//!
//! ```bash
//! cargo run --release --example autoswitch
//! ```

use psgd::algo::autoswitch::{AutoSwitchConfig, AutoSwitchDriver};
use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::sqm::{SqmConfig, SqmDriver};
use psgd::algo::{Driver, StopRule};
use psgd::bench::plot::AsciiPlot;
use psgd::cluster::{Cluster, CostModel};
use psgd::data::partition::Partition;
use psgd::data::synth::SynthConfig;
use psgd::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let nodes = args.usize("nodes", 8);
    let iters = args.usize("iters", 50);
    let data = SynthConfig {
        n_examples: args.usize("examples", 20_000),
        n_features: args.usize("features", 5_000),
        nnz_per_example: 12,
        ..SynthConfig::default()
    }
    .generate(42);
    let lam = 1e-5 * data.n_examples() as f64;
    let part = Partition::shuffled(data.n_examples(), nodes, 3);
    let make = || Cluster::partition_with(data.clone(), &part, CostModel::default());

    // high-accuracy reference
    let mut ref_cluster = Cluster::partition(data.clone(), 1, CostModel::free());
    let mut rcfg = SqmConfig { lam, ..Default::default() };
    rcfg.tron.eps = 1e-12;
    let fstar = SqmDriver::new(rcfg)
        .run(&mut ref_cluster, None, &StopRule::iters(300))
        .f;

    let stop = StopRule::iters(iters);
    let mut traces = Vec::new();
    {
        let mut c = make();
        let run = FsDriver::new(FsConfig { lam, epochs: 2, ..Default::default() })
            .run(&mut c, None, &stop);
        traces.push(run.trace);
    }
    {
        let mut c = make();
        let run = SqmDriver::new(SqmConfig { lam, ..Default::default() })
            .run(&mut c, None, &stop);
        traces.push(run.trace);
    }
    {
        let mut c = make();
        let cfg = AutoSwitchConfig {
            fs: FsConfig { lam, epochs: 2, ..Default::default() },
            switch_gnorm: args.f64("switch-gnorm", 3e-2),
            ..Default::default()
        };
        let run = AutoSwitchDriver::new(cfg).run(&mut c, None, &stop);
        traces.push(run.trace);
    }

    println!("f* = {fstar:.8e}\n");
    println!("method      final-gap    passes   sim-seconds");
    for t in &traces {
        let last = t.points.last().unwrap();
        println!(
            "{:<11} {:10.3e} {:9} {:10.2}",
            t.label,
            (last.f - fstar) / fstar,
            last.comm_passes,
            last.seconds
        );
    }
    let series: Vec<(String, Vec<(f64, f64)>)> = traces
        .iter()
        .map(|t| {
            (
                t.label.clone(),
                t.points
                    .iter()
                    .map(|p| (p.comm_passes, (p.f - fstar) / fstar))
                    .filter(|&(_, g)| g > 0.0)
                    .collect(),
            )
        })
        .collect();
    println!(
        "\n{}",
        AsciiPlot::default().render("(f - f*)/f* vs communication passes", &series)
    );
}
