//! Quickstart: train a linear classifier with the paper's method
//! (Algorithm 1, "FS-2") on a simulated 8-node cluster in under a
//! minute, and watch the convergence trace.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::{Driver, StopRule};
use psgd::cluster::{Cluster, CostModel};
use psgd::data::stats::DataStats;
use psgd::data::synth::SynthConfig;
use psgd::loss::LossKind;

fn main() {
    // 1. a kdd2010-shaped synthetic dataset (small scale)
    let data = SynthConfig {
        n_examples: 20_000,
        n_features: 30_000,
        nnz_per_example: 20,
        ..SynthConfig::default()
    }
    .generate(42);
    println!("data: {}", DataStats::compute(&data).render());
    let (train, test) = data.split(0.9, 7);

    // 2. an 8-node simulated cluster with the default (1 Gbit/s,
    //    0.5 ms latency) AllReduce-tree cost model
    let lam = 1e-5 * train.n_examples() as f64;
    let mut cluster = Cluster::partition(train, 8, CostModel::default());

    // 3. FS-2: two SVRG epochs per node per outer iteration
    let driver = FsDriver::new(FsConfig {
        loss: LossKind::Logistic,
        lam,
        epochs: 2,
        ..Default::default()
    });
    let run = driver.run(&mut cluster, Some(&test), &StopRule::iters(15));

    println!("\n iter        f          ‖g‖    passes  sim-sec   AUPRC");
    for p in &run.trace.points {
        println!(
            "{:5} {:12.4e} {:10.3e} {:7} {:8.2} {:7.4}",
            p.iter, p.f, p.gnorm, p.comm_passes, p.seconds, p.auprc
        );
    }
    println!(
        "\nfinal objective {:.6e} after {} communication passes \
         ({:.2} simulated seconds)",
        run.f,
        run.ledger.comm_passes,
        run.ledger.seconds()
    );
}
