//! Figure-1 reproduction driver: FS-s vs SQM vs Hybrid on
//! kdd2010-shaped data, emitting all three panels (gap vs comm passes,
//! gap vs simulated time, AUPRC vs time) for a node count, as CSV files
//! plus terminal ASCII plots.
//!
//! ```bash
//! cargo run --release --example figure1 -- --nodes 25
//! cargo run --release --example figure1 -- --nodes 100 --full  # repro scale
//! ```

use psgd::bench::figure1::{self, Figure1Config, Panel};
use psgd::bench::plot::AsciiPlot;
use psgd::util::cli::Args;
use psgd::util::csv::Table;

fn main() {
    let args = Args::from_env();
    let nodes = args.usize("nodes", 25);
    let mut cfg = if args.bool("full", false) {
        Figure1Config::full(nodes)
    } else {
        Figure1Config::small(nodes)
    };
    cfg.examples = args.usize("examples", cfg.examples);
    cfg.features = args.usize("features", cfg.features);
    cfg.iters = args.usize("iters", cfg.iters);
    cfg.seed = args.usize("seed", 42) as u64;
    let out_dir = args.get_or("out-dir", "results").to_string();

    eprintln!("figure1: {cfg:?}");
    let t0 = std::time::Instant::now();
    let out = figure1::run(&cfg);
    eprintln!(
        "completed in {:.1}s wall ({})",
        t0.elapsed().as_secs_f64(),
        out.config_label
    );
    println!("f* = {:.8e}", out.f_star);

    // CSV per method
    for trace in &out.traces {
        let path = format!("{out_dir}/fig1_{nodes}nodes_{}.csv", trace.label);
        trace.to_table(out.f_star).save(&path).expect("write csv");
        println!("wrote {path}");
    }
    // combined per-panel CSV (label, x, y) for external plotting
    for (panel, name) in [
        (Panel::GapVsPasses, "gap_vs_passes"),
        (Panel::GapVsTime, "gap_vs_time"),
        (Panel::AuprcVsTime, "auprc_vs_time"),
    ] {
        let mut t = Table::new(&["series", "x", "y"]);
        for (si, trace) in out.traces.iter().enumerate() {
            for (x, y) in panel.series(trace, out.f_star) {
                t.push(vec![si as f64, x, y]);
            }
        }
        let path = format!("{out_dir}/fig1_{nodes}nodes_{name}.csv");
        t.save(&path).expect("write panel csv");
        println!("wrote {path}  (series ids: {:?})",
            out.traces.iter().map(|t| t.label.clone()).collect::<Vec<_>>());
    }

    // terminal panels
    for panel in [Panel::GapVsPasses, Panel::GapVsTime, Panel::AuprcVsTime] {
        let series: Vec<(String, Vec<(f64, f64)>)> = out
            .traces
            .iter()
            .map(|t| (t.label.clone(), panel.series(t, out.f_star)))
            .collect();
        let plot = AsciiPlot { log_y: panel.log_y(), ..Default::default() };
        println!(
            "\n=== {} — {} ===\n{}",
            panel.title(),
            out.config_label,
            plot.render(panel.title(), &series)
        );
    }
}
