//! §Discussion (a) extension: non-convex point losses. The paper notes
//! that replacing L_p by a convex approximation keeps convergence
//! provable, and that *practically* one can run the non-convex f̂_p
//! directly as long as the inner optimization is stopped early enough
//! that d_p stays a descent direction.
//!
//! This example uses the sigmoid-like smoothed ramp loss
//! l(z, y) = 1/(1 + e^{yz}) (bounded, non-convex) and shows:
//! (1) FS-style outer iterations with early-stopped inner solves still
//!     monotonically decrease the (non-convex) objective — the line
//!     search + safeguard make that unconditional;
//! (2) warm-started from a few convex (logistic) FS iterations — the
//!     practical recipe — the ramp refinement keeps/improves AUPRC
//!     while shrinking the bounded non-convex risk.
//!
//! The non-convex loss lives here (not in `loss::LossKind`) exactly
//! because the core library's convex drivers must not accept it.
//!
//! ```bash
//! cargo run --release --example nonconvex
//! ```

use psgd::cluster::{Cluster, CostModel};
use psgd::data::synth::SynthConfig;
use psgd::linalg::dense;
use psgd::metrics::auprc::auprc;
use psgd::opt::linesearch::{strong_wolfe, WolfeParams};
use psgd::util::cli::Args;
use psgd::util::rng::Rng;

/// smoothed ramp (sigmoid) loss: l = σ(−yz), l' = −y σ(−yz)(1−σ(−yz))
fn sig(t: f64) -> f64 {
    if t >= 0.0 {
        1.0 / (1.0 + (-t).exp())
    } else {
        let e = t.exp();
        e / (1.0 + e)
    }
}

fn loss_val(z: f64, y: f64) -> f64 {
    sig(-y * z)
}

fn loss_deriv(z: f64, y: f64) -> f64 {
    let s = sig(-y * z);
    -y * s * (1.0 - s)
}

/// zᵢ = xᵢ·w against a *global* w — shards store local column ids, so
/// the example translates through the shard's support dictionary.
fn row_dot_global(s: &psgd::cluster::Shard, i: usize, w: &[f64]) -> f64 {
    let (cols, vals) = s.xl.row(i);
    cols.iter()
        .zip(vals)
        .map(|(&c, &v)| v as f64 * w[s.map.support[c as usize] as usize])
        .sum()
}

/// out ← out + α·xᵢ scattered to global coordinates.
fn add_row_global(
    s: &psgd::cluster::Shard,
    i: usize,
    alpha: f64,
    out: &mut [f64],
) {
    let (cols, vals) = s.xl.row(i);
    for (&c, &v) in cols.iter().zip(vals) {
        out[s.map.support[c as usize] as usize] += alpha * v as f64;
    }
}

fn main() {
    let args = Args::from_env();
    let nodes = args.usize("nodes", 6);
    let iters = args.usize("iters", 25);
    // a *noisy* problem where the bounded ramp loss shines (outliers)
    let data = SynthConfig {
        n_examples: 10_000,
        n_features: 15_000,
        nnz_per_example: 20,
        label_noise: 0.10,
        ..SynthConfig::default()
    }
    .generate(11);
    let (train, test) = data.split(0.9, 5);
    let lam = 1e-5 * train.n_examples() as f64;
    let mut cluster = Cluster::partition(train, nodes, CostModel::default());
    let dim = cluster.dim;

    // objective diagnostics over all shards
    let f_of = |c: &Cluster, w: &[f64]| -> f64 {
        let mut v = 0.5 * lam * dense::norm_sq(w);
        for s in &c.shards {
            for i in 0..s.xl.n_rows() {
                v += loss_val(row_dot_global(s, i, w), s.y[i]);
            }
        }
        v
    };

    // warm start: a few convex FS iterations (paper's practical advice:
    // non-convex f̂_p needs care; a convex head start is the cheap fix)
    let mut w = {
        use psgd::algo::fs::{FsConfig, FsDriver};
        use psgd::algo::{Driver, StopRule};
        use psgd::loss::LossKind;
        let run = FsDriver::new(FsConfig {
            loss: LossKind::Logistic,
            lam,
            epochs: 2,
            ..Default::default()
        })
        .run(&mut cluster, Some(&test), &StopRule::iters(12));
        println!(
            "warm start: 12 convex FS iters -> f_log {:.4e}, AUPRC {:.4}\n",
            run.f,
            run.trace.last().unwrap().auprc
        );
        run.w
    };
    let mut rng = Rng::new(3);
    println!("iter        f        ‖g‖       step    AUPRC  safeguarded");
    for r in 0..iters {
        // global gradient
        let mut g = vec![0.0; dim];
        for s in &cluster.shards {
            for i in 0..s.xl.n_rows() {
                let rr = loss_deriv(row_dot_global(s, i, &w), s.y[i]);
                if rr != 0.0 {
                    add_row_global(s, i, rr, &mut g);
                }
            }
        }
        dense::axpy(lam, &w, &mut g);
        cluster.ledger.comm_passes += 2.0;
        let gnorm = dense::norm(&g);

        // per-node EARLY-STOPPED inner solves on the non-convex f̂_p:
        // a few plain SGD steps (early stopping is what keeps d_p
        // descent-ish, per the paper's discussion)
        let mut dirs: Vec<Vec<f64>> = Vec::new();
        for (p, s) in cluster.shards.iter().enumerate() {
            let n_p = s.xl.n_rows();
            // tilt = g − λw − ∇L_p(w)
            let mut gl = vec![0.0; dim];
            for i in 0..n_p {
                let rr = loss_deriv(row_dot_global(s, i, &w), s.y[i]);
                if rr != 0.0 {
                    add_row_global(s, i, rr, &mut gl);
                }
            }
            let tilt: Vec<f64> =
                (0..dim).map(|j| g[j] - lam * w[j] - gl[j]).collect();
            let mut wp = w.clone();
            let mut srng = rng.fork(p as u64 + (r as u64) << 8);
            let lr = 2.0 / (1.0 + lam);
            // HALF an epoch: early stopping
            for _ in 0..(3 * n_p) / 4 {
                let i = srng.below(n_p);
                let zi = row_dot_global(s, i, &wp);
                let rr = loss_deriv(zi, s.y[i]);
                // dense part (λw + tilt) applied sparsely-ish: cheap
                // two-term axpy since dim is small here
                for j in 0..dim {
                    wp[j] -= lr / n_p as f64 * (lam * wp[j] + tilt[j]);
                }
                if rr != 0.0 {
                    add_row_global(s, i, -lr * rr, &mut wp);
                }
            }
            dirs.push(dense::sub(&wp, &w));
        }
        // safeguard (step 6) — essential in the non-convex case
        let mut safeguarded = 0;
        for dp in dirs.iter_mut() {
            if dense::dot(dp, &g) >= 0.0 {
                dp.iter_mut().zip(&g).for_each(|(v, gj)| *v = -gj);
                safeguarded += 1;
            }
        }
        let mut dir = vec![0.0; dim];
        for dp in &dirs {
            dense::axpy(1.0 / dirs.len() as f64, dp, &mut dir);
        }
        cluster.ledger.comm_passes += 2.0;

        // Armijo–Wolfe line search on the true (non-convex) objective
        let mut z: Vec<Vec<f64>> = Vec::new();
        let mut dz: Vec<Vec<f64>> = Vec::new();
        for s in &cluster.shards {
            let mut a = vec![0.0; s.xl.n_rows()];
            let mut b = vec![0.0; s.xl.n_rows()];
            for i in 0..s.xl.n_rows() {
                a[i] = row_dot_global(s, i, &w);
                b[i] = row_dot_global(s, i, &dir);
            }
            z.push(a);
            dz.push(b);
        }
        let wd = dense::dot(&w, &dir);
        let dd = dense::norm_sq(&dir);
        let ww = dense::norm_sq(&w);
        let phi = |t: f64| {
            let mut v = 0.5 * lam * (ww + 2.0 * t * wd + t * t * dd);
            let mut dv = lam * (wd + t * dd);
            for (s, (zs, dzs)) in cluster.shards.iter().zip(z.iter().zip(&dz)) {
                for i in 0..s.xl.n_rows() {
                    let zt = zs[i] + t * dzs[i];
                    v += loss_val(zt, s.y[i]);
                    dv += dzs[i] * loss_deriv(zt, s.y[i]);
                }
            }
            (v, dv)
        };
        let t = strong_wolfe(phi, &WolfeParams::default())
            .map(|r| r.t)
            .unwrap_or(0.0);
        dense::axpy(t, &dir, &mut w);

        // test AUPRC
        let mut scores = vec![0.0; test.n_examples()];
        test.x.matvec(&w, &mut scores);
        let a = auprc(&scores, &test.y);
        println!(
            "{r:4} {:10.4e} {gnorm:9.3e} {t:9.4} {a:8.4} {safeguarded:6}",
            f_of(&cluster, &w)
        );
    }
    println!(
        "\nnon-convex ramp loss trained by FS-style outer iterations; \
         monotone descent held via Armijo–Wolfe + safeguard."
    );
}
