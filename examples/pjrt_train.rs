//! End-to-end THREE-LAYER driver (DESIGN.md §3): trains a dense
//! logistic-regression model with Algorithm 1 where **every** per-node
//! compute step — batch gradient, SVRG epochs, line-search margins —
//! executes as an AOT-compiled XLA artifact (L2 JAX graph embedding the
//! L1 Pallas kernels), loaded and driven from the Rust coordinator via
//! PJRT. Python is not running; only `artifacts/*.hlo.txt` is used.
//!
//! ```bash
//! make artifacts && cargo run --release --example pjrt_train -- --nodes 4
//! ```
//!
//! Prints the loss curve and per-phase executable latencies; the run is
//! recorded in EXPERIMENTS.md §End-to-end.

use psgd::linalg::dense;
use psgd::loss::LossKind;
use psgd::metrics::auprc::auprc;
use psgd::opt::linesearch::{strong_wolfe, WolfeParams};
use psgd::runtime::DenseRuntime;
use psgd::util::cli::Args;
use psgd::util::rng::Rng;
use std::time::Instant;

struct NodeData {
    x: Vec<f32>,
    y: Vec<f32>,
}

fn main() {
    let args = Args::from_env();
    let nodes = args.usize("nodes", 4);
    let iters = args.usize("iters", 12);
    let epochs = args.usize("epochs", 2); // s
    let rel_lambda = args.f64("rel-lambda", 1e-4);

    let rt = match DenseRuntime::load(args.get_or("artifacts", "artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load artifacts ({e:#}); run `make artifacts`");
            std::process::exit(1);
        }
    };
    let (n, d) = (rt.manifest.n, rt.manifest.d);
    let loss = LossKind::parse(&rt.manifest.loss).expect("loss");
    println!(
        "platform {} | artifact shapes: {} examples/node x {} features, \
         batch {}, loss {}",
        rt.platform(),
        n,
        d,
        rt.manifest.batch,
        rt.manifest.loss
    );

    // ---- synthetic dense problem with a planted separator ----
    let mut rng = Rng::new(args.usize("seed", 42) as u64);
    let w_true: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut gen_node = |rng: &mut Rng| -> NodeData {
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let row: Vec<f64> = (0..d).map(|_| rng.normal() * 0.2).collect();
            let margin: f64 =
                row.iter().zip(&w_true).map(|(a, b)| a * b).sum::<f64>()
                    / (d as f64).sqrt();
            y.push(if margin + 0.1 * rng.normal() >= 0.0 { 1.0 } else { -1.0 }
                as f32);
            x.extend(row.iter().map(|&v| v as f32));
        }
        NodeData { x, y }
    };
    let shards: Vec<NodeData> = (0..nodes).map(|_| gen_node(&mut rng)).collect();
    let test = gen_node(&mut rng);
    let n_total = (nodes * n) as f64;
    let lam = (rel_lambda * n_total) as f32;

    let mut w = vec![0.0f32; d];
    let mut perm_rng = Rng::new(7);
    let (mut t_grad, mut t_svrg, mut t_margins) = (0.0f64, 0.0f64, 0.0f64);

    println!("\niter       f           ‖g‖      step     AUPRC   wall(s)");
    for r in 0..iters {
        let it0 = Instant::now();
        // ---- step 1: distributed gradient via the value_grad artifact ----
        let t0 = Instant::now();
        let per_node: Vec<_> = shards
            .iter()
            .map(|s| rt.value_grad(&w, &s.x, &s.y).expect("value_grad"))
            .collect();
        t_grad += t0.elapsed().as_secs_f64();
        let loss_sum: f64 = per_node.iter().map(|o| o.loss_sum).sum();
        let wnorm2: f64 =
            w.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        let f = loss_sum + 0.5 * lam as f64 * wnorm2;
        let mut g = vec![0.0f64; d];
        for o in &per_node {
            for j in 0..d {
                g[j] += o.grad[j] as f64;
            }
        }
        for j in 0..d {
            g[j] += lam as f64 * w[j] as f64;
        }
        let gnorm = dense::norm(&g);

        // ---- steps 3–5: per-node tilted SVRG via the svrg_epoch artifact ----
        let t0 = Instant::now();
        let lr = 1.0 / (lam as f64 + 0.25 * 0.04 * (n * d) as f64 / 16.0);
        let mut dirs: Vec<Vec<f64>> = Vec::with_capacity(nodes);
        for s in &shards {
            // tilt = g − λw − ∇L_p(w)
            let o = rt.value_grad(&w, &s.x, &s.y).expect("grad for tilt");
            let tilt: Vec<f32> = (0..d)
                .map(|j| {
                    (g[j] - lam as f64 * w[j] as f64 - o.grad[j] as f64) as f32
                })
                .collect();
            let mut w_p = w.clone();
            for _ in 0..epochs {
                let perm: Vec<i32> = perm_rng
                    .permutation(n)
                    .into_iter()
                    .map(|v| v as i32)
                    .collect();
                w_p = rt
                    .svrg_epoch(&w_p, &s.x, &s.y, &tilt, lam, lr as f32, &perm)
                    .expect("svrg_epoch");
            }
            dirs.push(
                (0..d).map(|j| w_p[j] as f64 - w[j] as f64).collect(),
            );
        }
        t_svrg += t0.elapsed().as_secs_f64();
        // safeguard + average
        let mut dir = vec![0.0f64; d];
        for dp in &mut dirs {
            if dense::dot(dp, &g) >= 0.0 {
                // replace by −g (step 6)
                dp.iter_mut().zip(&g).for_each(|(v, gj)| *v = -gj);
            }
            dense::axpy(1.0 / nodes as f64, dp, &mut dir);
        }

        // ---- step 8: line search on margins via the margins artifact ----
        let t0 = Instant::now();
        let dir_f32: Vec<f32> = dir.iter().map(|&v| v as f32).collect();
        let mut z_parts = Vec::with_capacity(nodes);
        let mut dz_parts = Vec::with_capacity(nodes);
        for (s, o) in shards.iter().zip(&per_node) {
            z_parts.push(o.margins.clone());
            dz_parts.push(rt.margins(&s.x, &dir_f32).expect("margins"));
        }
        t_margins += t0.elapsed().as_secs_f64();
        let wd: f64 = w
            .iter()
            .zip(&dir)
            .map(|(&wi, &di)| wi as f64 * di)
            .sum();
        let dd = dense::norm_sq(&dir);
        let phi = |t: f64| -> (f64, f64) {
            let mut v = 0.5
                * lam as f64
                * (wnorm2 + 2.0 * t * wd + t * t * dd);
            let mut dv = lam as f64 * (wd + t * dd);
            for (p, (zs, dzs)) in
                shards.iter().zip(z_parts.iter().zip(&dz_parts))
            {
                for i in 0..n {
                    let zt = zs[i] as f64 + t * dzs[i] as f64;
                    v += loss.value(zt, p.y[i] as f64);
                    dv += dzs[i] as f64 * loss.deriv(zt, p.y[i] as f64);
                }
            }
            (v, dv)
        };
        let step = match strong_wolfe(phi, &WolfeParams::default()) {
            Ok(res) => res.t,
            Err(_) => 0.0,
        };
        for j in 0..d {
            w[j] += (step * dir[j]) as f32;
        }

        // test AUPRC through the margins artifact
        let scores = rt.margins(&test.x, &w).expect("test margins");
        let a = auprc(
            &scores.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            &test.y.iter().map(|&v| v as f64).collect::<Vec<_>>(),
        );
        println!(
            "{r:4} {f:12.5e} {gnorm:10.3e} {step:8.4} {a:8.4} {:8.2}",
            it0.elapsed().as_secs_f64()
        );
        if gnorm < 1e-7 {
            break;
        }
    }
    println!(
        "\nexecutable wall-times: value_grad {t_grad:.2}s | svrg_epoch \
         {t_svrg:.2}s | margins {t_margins:.2}s"
    );
    println!(
        "three-layer composition OK: rust coordinator drove {} XLA \
         executables end-to-end",
        3
    );
}
